"""The model family: decoder-only LM over six architecture types
(dense / moe / ssm / hybrid / vlm / audio), pure JAX, scan-over-layers.

Distribution strategy (see repro.sharding.rules):
  * matmuls / norms / embeddings: GSPMD via sharding constraints;
  * attention: sequence-sharded shard_map islands (prefill/train: q over the
    model axis with gathered KV; decode: distributed online softmax over the
    sequence-sharded KV cache);
  * MoE: shard_map island (repro.models.moe), tp or ep expert sharding.

Three entry points, matching the assigned shapes:
  ``train_loss``   — tokens/embeddings -> mean CE (+ MoE aux);
  ``prefill``      — fills a KV/SSM cache, returns last-token logits;
  ``decode_step``  — ONE new token against a seq_len cache.
"""

from __future__ import annotations

import dataclasses
import math
from collections import namedtuple
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.sharding.rules import Rules

Leaf = namedtuple("Leaf", ["shape", "spec", "init"])


def _normal(scale: float):
    def init(key, shape):
        return scale * jax.random.normal(key, shape, jnp.float32)
    return init


def _ones(key, shape):
    return jnp.ones(shape, jnp.float32)


def _zeros(key, shape):
    return jnp.zeros(shape, jnp.float32)


def _a_log_init(key, shape):
    # A uniformly in [1, 16] (Mamba2 default)
    a = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
    return jnp.log(a)


def _dt_bias_init(key, shape):
    # dt in [1e-3, 1e-1] log-uniform, stored as inverse-softplus
    dt = jnp.exp(jax.random.uniform(key, shape, jnp.float32,
                                    math.log(1e-3), math.log(1e-1)))
    return dt + jnp.log(-jnp.expm1(-dt))


# ---------------------------------------------------------------------------
# Parameter schema (shapes + shardings + init), single source of truth
# ---------------------------------------------------------------------------

def _attn_leaves(cfg: ModelConfig, r: Rules, stacked: bool) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    pre = (cfg.num_layers,) if stacked else ()
    lp = (None,) if stacked else ()
    s_in = _normal(0.02)
    s_out = _normal(0.02 / math.sqrt(2 * cfg.num_layers))
    leaves = {
        "attn_norm": Leaf(pre + (d,), P(*lp, None), _ones),
        "wq": Leaf(pre + (d, nq * hd), P(*lp, r.dp(d), r.tp(nq * hd)), s_in),
        "wk": Leaf(pre + (d, nkv * hd), P(*lp, r.dp(d), r.tp(nkv * hd)), s_in),
        "wv": Leaf(pre + (d, nkv * hd), P(*lp, r.dp(d), r.tp(nkv * hd)), s_in),
        "wo": Leaf(pre + (nq * hd, d), P(*lp, r.tp(nq * hd), r.dp(d)), s_out),
    }
    if cfg.qkv_bias:
        leaves["bq"] = Leaf(pre + (nq * hd,), P(*lp, r.tp(nq * hd)), _zeros)
        leaves["bk"] = Leaf(pre + (nkv * hd,), P(*lp, r.tp(nkv * hd)), _zeros)
        leaves["bv"] = Leaf(pre + (nkv * hd,), P(*lp, r.tp(nkv * hd)), _zeros)
    return leaves


def _mlp_leaves(cfg: ModelConfig, r: Rules) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    lcount = cfg.num_layers
    s_in = _normal(0.02)
    s_out = _normal(0.02 / math.sqrt(2 * lcount))
    base = {"mlp_norm": Leaf((lcount, d), P(None, None), _ones)}
    if cfg.moe:
        e = cfg.moe.num_experts
        if r.moe_sharding == "ep" and e % r.model_size == 0:
            espec = (r.model_axis, r.dp(d), None)
            espec_dn = (r.model_axis, None, r.dp(d))
        else:
            espec = (None, r.dp(d), r.tp(f))
            espec_dn = (None, r.tp(f), r.dp(d))
        base.update({
            "router": Leaf((lcount, d, e), P(None, r.dp(d), None), s_in),
            "w_gate": Leaf((lcount, e, d, f), P(None, *espec), s_in),
            "w_up": Leaf((lcount, e, d, f), P(None, *espec), s_in),
            "w_down": Leaf((lcount, e, f, d), P(None, *espec_dn), s_out),
        })
    else:
        base.update({
            "w_gate": Leaf((lcount, d, f), P(None, r.dp(d), r.tp(f)), s_in),
            "w_up": Leaf((lcount, d, f), P(None, r.dp(d), r.tp(f)), s_in),
            "w_down": Leaf((lcount, f, d), P(None, r.tp(f), r.dp(d)), s_out),
        })
    return base


def _ssm_leaves(cfg: ModelConfig, r: Rules) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    h = di // s.head_dim
    gn = s.ngroups * s.state_dim
    lcount = cfg.num_layers
    s_in = _normal(0.02)
    s_out = _normal(0.02 / math.sqrt(2 * lcount))
    return {
        "norm": Leaf((lcount, d), P(None, None), _ones),
        "z_proj": Leaf((lcount, d, di), P(None, r.dp(d), r.tp(di)), s_in),
        "x_proj": Leaf((lcount, d, di), P(None, r.dp(d), r.tp(di)), s_in),
        "B_proj": Leaf((lcount, d, gn), P(None, r.dp(d), None), s_in),
        "C_proj": Leaf((lcount, d, gn), P(None, r.dp(d), None), s_in),
        "dt_proj": Leaf((lcount, d, h), P(None, r.dp(d), r.tp(h)), s_in),
        "conv_x_w": Leaf((lcount, s.conv_width, di), P(None, None, r.tp(di)),
                         _normal(0.2)),
        "conv_x_b": Leaf((lcount, di), P(None, r.tp(di)), _zeros),
        "conv_B_w": Leaf((lcount, s.conv_width, gn), P(None, None, None),
                         _normal(0.2)),
        "conv_B_b": Leaf((lcount, gn), P(None, None), _zeros),
        "conv_C_w": Leaf((lcount, s.conv_width, gn), P(None, None, None),
                         _normal(0.2)),
        "conv_C_b": Leaf((lcount, gn), P(None, None), _zeros),
        "A_log": Leaf((lcount, h), P(None, r.tp(h)), _a_log_init),
        "ssm_D": Leaf((lcount, h), P(None, r.tp(h)), _ones),
        "dt_bias": Leaf((lcount, h), P(None, r.tp(h)), _dt_bias_init),
        "gate_norm": Leaf((lcount, di), P(None, r.tp(di)), _ones),
        "out_proj": Leaf((lcount, di, d), P(None, r.tp(di), r.dp(d)), s_out),
    }


def param_schema(cfg: ModelConfig, r: Rules) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    schema: dict = {
        "embed": Leaf((v, d), P(r.tp(v), r.dp(d)), _normal(0.02)),
        "final_norm": Leaf((d,), P(None), _ones),
    }
    if not cfg.tie_embeddings:
        schema["lm_head"] = Leaf((d, v), P(r.dp(d), r.tp(v)), _normal(0.02))
    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        layers = _attn_leaves(cfg, r, stacked=True)
        layers.update(_mlp_leaves(cfg, r))
        schema["layers"] = layers
    elif cfg.arch_type == "ssm":
        schema["layers"] = _ssm_leaves(cfg, r)
    elif cfg.arch_type == "hybrid":
        schema["layers"] = _ssm_leaves(cfg, r)
        shared = _attn_leaves(
            dataclasses.replace(cfg, num_layers=1), r, stacked=False)
        schema["shared_attn"] = shared
    return schema


def _is_leaf(x):
    return isinstance(x, Leaf)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig, rules: Rules):
        self.cfg = cfg
        self.rules = rules
        self.compute_dtype = jnp.dtype(cfg.dtype)

    # ----- params -----

    def init(self, key) -> dict:
        schema = param_schema(self.cfg, self.rules)
        flat, tree = jax.tree.flatten(schema, is_leaf=_is_leaf)
        keys = jax.random.split(key, len(flat))
        vals = [leaf.init(k, leaf.shape) for k, leaf in zip(keys, flat)]
        return jax.tree.unflatten(tree, vals)

    def param_specs(self) -> dict:
        schema = param_schema(self.cfg, self.rules)
        return jax.tree.map(lambda leaf: leaf.spec, schema, is_leaf=_is_leaf)

    def param_shapes(self) -> dict:
        schema = param_schema(self.cfg, self.rules)
        return jax.tree.map(
            lambda leaf: jax.ShapeDtypeStruct(leaf.shape, jnp.float32),
            schema, is_leaf=_is_leaf)

    def count_params(self) -> int:
        schema = param_schema(self.cfg, self.rules)
        return sum(math.prod(l.shape) for l in
                   jax.tree.leaves(schema, is_leaf=_is_leaf))

    # ----- attention shard_map islands -----

    def _seq_attn(self, batch: int, with_cache: bool, cache_w: int = 0,
                  seq_len: int = 0):
        """Prefill/train attention: q sequence-sharded over the model axis,
        KV gathered.  If ``with_cache``, also materializes the (sequence-
        sharded) KV cache for this layer."""
        cfg, r = self.cfg, self.rules
        window = cfg.sliding_window
        dp = r.dp(batch)
        tp = r.model_axis

        def body(q, k, v, q_pos, k_pos):
            out = attn.chunked_attention(
                q, k, v, q_pos, k_pos, window=window,
                q_chunk=r.q_chunk, k_chunk=r.k_chunk,
                skip_masked_blocks=r.skip_masked_blocks)
            if not with_cache:
                return out
            # build this shard's rows of the cache from the gathered k/v
            w, s = cache_w, seq_len
            w_loc = w // compat.axis_size(tp)
            my0 = jax.lax.axis_index(tp) * w_loc
            g = my0 + jnp.arange(w_loc)
            p_start = max(0, s - w)
            src = p_start + jnp.mod(g - p_start, w)
            valid = src < s
            safe = jnp.clip(src, 0, s - 1)
            kc = jnp.where(valid[None, :, None, None], k[:, safe], 0)
            vc = jnp.where(valid[None, :, None, None], v[:, safe], 0)
            sp = jnp.where(valid, src, -1).astype(jnp.int32)
            return out, kc, vc, sp

        in_specs = (P(dp, tp, None, None), P(dp, None, None, None),
                    P(dp, None, None, None), P(tp), P(None))
        if with_cache:
            out_specs = (P(dp, tp, None, None), P(dp, tp, None, None),
                         P(dp, tp, None, None), P(tp))
        else:
            out_specs = P(dp, tp, None, None)
        return compat.shard_map(body, mesh=r.mesh, in_specs=in_specs,
                                out_specs=out_specs, check_vma=False)

    def _decode_attn(self, batch: int):
        """One-token decode with distributed online softmax over the
        sequence-sharded cache; also appends the new token's k/v.

        The cache sequence dim shards over ``rules.cache_axes`` — just the
        model axis normally, or ALL mesh axes in the serving layout."""
        cfg, r = self.cfg, self.rules
        window = cfg.sliding_window
        dp = r.dp(batch)
        axes = r.cache_axes
        n_shards = math.prod(r.mesh.shape[a] for a in axes)

        def body(q, k1, v1, kc, vc, sp, pos):
            # append: global slot -> local row (drop if not ours)
            w_loc = kc.shape[1]
            w = w_loc * n_shards
            # flattened shard index in PartitionSpec axis order
            my = jnp.int32(0)
            for a in axes:
                my = my * r.mesh.shape[a] + jax.lax.axis_index(a)
            slot = pos % w
            ls = slot - my * w_loc
            ls = jnp.where((ls >= 0) & (ls < w_loc), ls, w_loc)  # OOB drops
            kc = kc.at[:, ls].set(k1[:, 0].astype(kc.dtype), mode="drop")
            vc = vc.at[:, ls].set(v1[:, 0].astype(vc.dtype), mode="drop")
            sp = sp.at[ls].set(pos.astype(jnp.int32), mode="drop")

            # distributed online softmax
            b, _, hq, dh = q.shape
            hkv = kc.shape[2]
            g = hq // hkv
            qg = q.reshape(b, hkv, g, dh).astype(jnp.float32)
            s = jnp.einsum("bhgd,bkhd->bhgk", qg,
                           kc.astype(jnp.float32)) / math.sqrt(dh)
            ok = (sp >= 0) & (sp <= pos)
            if window > 0:
                ok &= sp > (pos - window)
            s = s + jnp.where(ok, 0.0, attn.NEG_INF)[None, None, None, :]
            m = jax.lax.pmax(jnp.max(s, axis=-1), axes)
            p = jnp.exp(s - m[..., None])
            l = jax.lax.psum(jnp.sum(p, axis=-1), axes)
            o = jax.lax.psum(
                jnp.einsum("bhgk,bkhd->bhgd", p,
                           vc.astype(jnp.float32)), axes)
            o = o / jnp.maximum(l, 1e-30)[..., None]
            out = o.reshape(b, 1, hq, dh).astype(q.dtype)
            return out, kc, vc, sp

        in_specs = (P(dp, None, None, None), P(dp, None, None, None),
                    P(dp, None, None, None), P(dp, axes, None, None),
                    P(dp, axes, None, None), P(axes), P())
        out_specs = (P(dp, None, None, None), P(dp, axes, None, None),
                     P(dp, axes, None, None), P(axes))
        return compat.shard_map(body, mesh=r.mesh, in_specs=in_specs,
                                out_specs=out_specs, check_vma=False)

    # ----- attention sublayer -----

    def _qkv(self, p, a):
        cfg = self.cfg
        b, s, _ = a.shape
        dt = a.dtype
        q = a @ p["wq"].astype(dt)
        k = a @ p["wk"].astype(dt)
        v = a @ p["wv"].astype(dt)
        if cfg.qkv_bias:
            q = q + p["bq"].astype(dt)
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
        q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
        k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
        v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
        return q, k, v

    def _rope(self, x, positions, mrope_pos):
        cfg = self.cfg
        if cfg.mrope and mrope_pos is not None:
            return L.apply_mrope(x, mrope_pos, cfg.rope_theta,
                                 cfg.mrope_sections)
        return L.apply_rope(x, positions, cfg.rope_theta)

    def _act_seq(self, seq: int) -> int:
        """Sequence length to pass to act_btd: sequence-sharded residuals
        apply only to the attention families (the SSM conv/scan needs the
        full sequence locally)."""
        if self.cfg.arch_type in ("dense", "moe", "vlm", "audio"):
            return seq
        return 0

    def attention_sublayer(self, p, h, *, mode, cache, positions,
                           mrope_pos=None, cache_w: int = 0):
        """Returns (h', new_cache or None)."""
        cfg, r = self.cfg, self.rules
        b, s, d = h.shape
        a = L.rms_norm(h, p["attn_norm"], cfg.norm_eps)
        q, k, v = self._qkv(p, a)
        if mode != "decode":
            # settle into the sequence-sharded layout BEFORE RoPE so the
            # partitioner doesn't bounce through head-sharded intermediates
            seq_spec = P(r.dp(b), r.model_axis, None, None)
            q = r.constrain(q, seq_spec)
            k = r.constrain(k, seq_spec)
            v = r.constrain(v, seq_spec)
        rope_pos = positions if positions.ndim >= 1 else positions[None]
        q = self._rope(q, rope_pos, mrope_pos)
        k = self._rope(k, rope_pos, mrope_pos)

        new_cache = None
        if mode == "train":
            kpos = positions if positions.ndim == 1 else positions[0]
            out = self._seq_attn(b, with_cache=False)(q, k, v, kpos, kpos)
        elif mode == "prefill":
            kpos = positions if positions.ndim == 1 else positions[0]
            out, kc, vc, sp = self._seq_attn(
                b, with_cache=True, cache_w=cache_w, seq_len=s)(
                    q, k, v, kpos, kpos)
            new_cache = {"k": kc.astype(self.compute_dtype),
                         "v": vc.astype(self.compute_dtype), "slot_pos": sp}
        else:  # decode
            pos = positions if positions.ndim == 0 else positions.reshape(())
            out, kc, vc, sp = self._decode_attn(b)(
                q, k, v, cache["k"], cache["v"], cache["slot_pos"], pos)
            new_cache = {"k": kc, "v": vc, "slot_pos": sp}

        out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
        h = h + out @ p["wo"].astype(out.dtype)
        return r.constrain(h, r.act_btd(b, self._act_seq(s))), new_cache

    # ----- mlp / moe sublayer -----

    def mlp_sublayer(self, p, h):
        cfg, r = self.cfg, self.rules
        b = h.shape[0]
        m = L.rms_norm(h, p["mlp_norm"], cfg.norm_eps)
        if cfg.moe:
            use_ep = (r.moe_sharding == "ep"
                      and cfg.moe.num_experts % r.model_size == 0)
            fax = r.tp(cfg.d_ff)
            feature_axes = fax if isinstance(fax, tuple) else (
                (fax,) if fax else (r.model_axis,))
            island = moe_lib.make_sharded_moe(
                r.mesh, moe=cfg.moe, model_axis=r.model_axis,
                data_axes=r.data_axes,
                moe_sharding="ep" if use_ep else "tp",
                batch_spec=r.dp(b), feature_axes=feature_axes)
            y, aux = island(m, p["router"], p["w_gate"], p["w_up"],
                            p["w_down"])
        else:
            y = L.swiglu(m, p["w_gate"], p["w_up"], p["w_down"])
            aux = jnp.zeros((), jnp.float32)
        h = h + y
        return r.constrain(h, r.act_btd(b, self._act_seq(h.shape[1]))), aux

    # ----- ssm sublayer -----

    def mamba_sublayer(self, p, h, *, mode, cache):
        cfg, r = self.cfg, self.rules
        s_cfg = cfg.ssm
        b, s, d = h.shape
        di = s_cfg.expand * d
        nh = di // s_cfg.head_dim
        pdim = s_cfg.head_dim
        g, n = s_cfg.ngroups, s_cfg.state_dim
        dt_c = h.dtype

        a = L.rms_norm(h, p["norm"], cfg.norm_eps)
        z = a @ p["z_proj"].astype(dt_c)
        x = a @ p["x_proj"].astype(dt_c)
        Bm = a @ p["B_proj"].astype(dt_c)
        Cm = a @ p["C_proj"].astype(dt_c)
        dtr = a @ p["dt_proj"].astype(dt_c)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))

        new_cache = None
        if mode in ("train", "prefill"):
            init_cx = cache["conv_x"] if cache is not None else None
            init_cb = cache["conv_B"] if cache is not None else None
            init_cc = cache["conv_C"] if cache is not None else None
            x, cx = ssm_lib.causal_conv(x, p["conv_x_w"], p["conv_x_b"], init_cx)
            Bm, cb = ssm_lib.causal_conv(Bm, p["conv_B_w"], p["conv_B_b"], init_cb)
            Cm, cc = ssm_lib.causal_conv(Cm, p["conv_C_w"], p["conv_C_b"], init_cc)
            dt = jax.nn.softplus(dtr.astype(jnp.float32)
                                 + p["dt_bias"].astype(jnp.float32))
            xh = x.reshape(b, s, nh, pdim)
            xh = r.constrain(xh, P(r.dp(b), None, r.tp(nh), None))
            y, state = ssm_lib.ssd_chunked(
                xh, dt, A, Bm.reshape(b, s, g, n), Cm.reshape(b, s, g, n),
                p["ssm_D"].astype(jnp.float32),
                chunk=r.ssm_chunk or s_cfg.chunk_size,
                init_state=cache["ssm"] if cache is not None else None,
                return_state=True,
                compute_dtype=jnp.dtype(r.ssd_compute_dtype))
            if mode == "prefill":
                new_cache = {"ssm": state.astype(jnp.float32),
                             "conv_x": cx, "conv_B": cb, "conv_C": cc}
            y = y.reshape(b, s, di)
        else:  # decode, s == 1
            x1, cx = ssm_lib.conv_decode_step(
                cache["conv_x"], x[:, 0], p["conv_x_w"], p["conv_x_b"])
            B1, cb = ssm_lib.conv_decode_step(
                cache["conv_B"], Bm[:, 0], p["conv_B_w"], p["conv_B_b"])
            C1, cc = ssm_lib.conv_decode_step(
                cache["conv_C"], Cm[:, 0], p["conv_C_w"], p["conv_C_b"])
            dt1 = jax.nn.softplus(dtr[:, 0].astype(jnp.float32)
                                  + p["dt_bias"].astype(jnp.float32))
            y1, state = ssm_lib.ssd_decode_step(
                cache["ssm"], x1.reshape(b, nh, pdim), dt1, A,
                B1.reshape(b, g, n), C1.reshape(b, g, n),
                p["ssm_D"].astype(jnp.float32))
            new_cache = {"ssm": state, "conv_x": cx, "conv_B": cb,
                         "conv_C": cc}
            y = y1.reshape(b, 1, di)

        gated = L.rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
        h = h + gated @ p["out_proj"].astype(dt_c)
        return r.constrain(h, r.act_btd(b)), new_cache

    # ----- layer stack -----

    def _transformer_layer(self, p, h, *, mode, cache, positions, mrope_pos,
                           cache_w):
        h, attn_cache = self.attention_sublayer(
            p, h, mode=mode, cache=cache, positions=positions,
            mrope_pos=mrope_pos, cache_w=cache_w)
        h, aux = self.mlp_sublayer(p, h)
        return h, aux, attn_cache

    def apply_layers(self, params, h, *, mode, caches=None, positions=None,
                     mrope_pos=None, cache_w: int = 0):
        """Run the layer stack.  Returns (h, aux_mean, new_caches)."""
        cfg, r = self.cfg, self.rules
        layers = params["layers"]

        if cfg.arch_type == "hybrid":
            return self._apply_hybrid(params, h, mode=mode, caches=caches,
                                      positions=positions, cache_w=cache_w)

        is_ssm = cfg.arch_type == "ssm"

        def body(carry, xs):
            h, aux = carry
            if mode == "decode" or (mode == "prefill" and is_ssm and
                                    caches is not None):
                p, layer_cache = xs
            else:
                p, layer_cache = xs, None
            if is_ssm:
                h, new_cache = self.mamba_sublayer(
                    p, h, mode=mode, cache=layer_cache)
                aux_i = jnp.zeros((), jnp.float32)
            else:
                h, aux_i, new_cache = self._transformer_layer(
                    p, h, mode=mode, cache=layer_cache, positions=positions,
                    mrope_pos=mrope_pos, cache_w=cache_w)
            if new_cache is None:
                new_cache = 0  # dummy ys
            return (h, aux + aux_i), new_cache

        if mode == "train" and r.remat:
            body = jax.checkpoint(body)

        if mode == "decode" or (mode == "prefill" and is_ssm
                                and caches is not None):
            xs = (layers, caches)
        else:
            xs = layers
        (h, aux), new_caches = jax.lax.scan(
            body, (h, jnp.zeros((), jnp.float32)), xs)
        if mode == "train":
            new_caches = None
        return h, aux / cfg.num_layers, new_caches

    def _apply_hybrid(self, params, h, *, mode, caches, positions, cache_w):
        """Zamba2: Mamba2 backbone, ONE shared attention block applied every
        ``shared_attention_every`` layers (unrolled; 38 small layers)."""
        cfg = self.cfg
        every = cfg.shared_attention_every
        shared_p = params["shared_attn"]
        layers = params["layers"]
        n_inv = -(-cfg.num_layers // every)

        mamba_caches, attn_caches = (caches if caches is not None
                                     else (None, None))
        new_mamba, new_attn = [], []
        aux = jnp.zeros((), jnp.float32)

        # per-layer activation checkpointing: the hybrid stack is unrolled
        # (non-uniform shared-attention schedule), so the scan-body remat
        # doesn't apply — without this every [L, L] SSD intermediate of all
        # 38 layers is saved for the backward pass (§Perf p2 iteration 2)
        remat_train = mode == "train" and self.rules.remat

        def attn_layer(shared_p, h):
            return self.attention_sublayer(
                shared_p, h, mode=mode, cache=None,
                positions=positions, cache_w=cache_w)[0]

        def mamba_layer(p_i, h):
            return self.mamba_sublayer(p_i, h, mode=mode, cache=None)[0]

        if remat_train:
            attn_layer = jax.checkpoint(attn_layer)
            mamba_layer = jax.checkpoint(mamba_layer)

        for i in range(cfg.num_layers):
            if i % every == 0:
                inv = i // every
                a_cache = (jax.tree.map(lambda x: x[inv], attn_caches)
                           if attn_caches is not None else None)
                if remat_train:
                    h = attn_layer(shared_p, h)
                    nc = None
                else:
                    h, nc = self.attention_sublayer(
                        shared_p, h, mode=mode, cache=a_cache,
                        positions=positions, cache_w=cache_w)
                if nc is not None:
                    new_attn.append(nc)
            p_i = jax.tree.map(lambda x: x[i], layers)
            m_cache = (jax.tree.map(lambda x: x[i], mamba_caches)
                       if mamba_caches is not None else None)
            if remat_train:
                h = mamba_layer(p_i, h)
                nmc = None
            else:
                h, nmc = self.mamba_sublayer(p_i, h, mode=mode,
                                             cache=m_cache)
            if nmc is not None:
                new_mamba.append(nmc)
        del n_inv
        new_caches = None
        if new_mamba or new_attn:
            stack = lambda xs: jax.tree.map(
                lambda *a: jnp.stack(a), *xs) if xs else None
            new_caches = (stack(new_mamba), stack(new_attn))
        return h, aux, new_caches

    # ----- entry points -----

    def _maybe_cast_params(self, params):
        """§Perf knob: cast fp32 master params to bf16 before use, so the
        FSDP all-gathers at the layer boundaries move half the bytes.

        The with_sharding_constraint on each bf16 copy is load-bearing:
        without it GSPMD is free to hoist the convert AFTER the all-gather
        (gathering fp32 and converting locally), which keeps the collective
        bytes unchanged — measured in §Perf iteration 1.  Pinning the bf16
        copy to the param's own (sharded) spec forces a shard-local convert,
        so the gather (and its reduce-scatter transpose in the backward
        pass) moves bf16."""
        if self.rules.param_gather_dtype != "bfloat16":
            return params
        specs = self.param_specs()
        return jax.tree.map(
            lambda x, s: self.rules.constrain(x.astype(jnp.bfloat16), s)
            if x.dtype == jnp.float32 else x, params, specs)

    def _embed_inputs(self, params, batch):
        cfg, r = self.cfg, self.rules
        if "embeddings" in batch:  # vlm / audio frontend stub output
            h = batch["embeddings"].astype(self.compute_dtype)
        else:
            h = L.embed(batch["tokens"], params["embed"], self.compute_dtype)
        b = h.shape[0]
        return r.constrain(h, r.act_btd(b, self._act_seq(h.shape[1])))

    def _logits(self, params, h):
        cfg, r = self.cfg, self.rules
        b = h.shape[0]
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        table = (params["embed"].T if cfg.tie_embeddings
                 else params["lm_head"])
        logits = L.unembed(h, table)
        return r.constrain(logits, r.act_logits(b, cfg.vocab_size))

    def train_loss(self, params, batch):
        """batch: tokens|embeddings [B,S(,D)], labels [B,S],
        optional mrope_pos [B,S,3].  Returns (loss, metrics)."""
        cfg = self.cfg
        params = self._maybe_cast_params(params)
        h = self._embed_inputs(params, batch)
        b, s = h.shape[0], h.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        h, aux, _ = self.apply_layers(
            params, h, mode="train", positions=positions,
            mrope_pos=batch.get("mrope_pos"))
        logits = self._logits(params, h)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        # label logit without gathering across the vocab-sharded dim
        onehot_ll = jnp.sum(
            jnp.where(jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
                      == labels[..., None], logits, 0.0), axis=-1)
        ce = jnp.mean(lse - onehot_ll)
        loss = ce
        if cfg.moe:
            loss = loss + cfg.moe.router_aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    def prefill(self, params, batch, *, cache_len: int):
        """Fill caches for ``batch`` (tokens/embeddings of length S).
        Returns (last_logits [B, V], caches)."""
        cfg = self.cfg
        params = self._maybe_cast_params(params)
        h = self._embed_inputs(params, batch)
        b, s = h.shape[0], h.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        w = self.cache_window(cache_len)
        h, _, caches = self.apply_layers(
            params, h, mode="prefill", positions=positions,
            mrope_pos=batch.get("mrope_pos"), cache_w=w)
        logits = self._logits(params, h[:, -1:])
        return logits[:, 0], caches

    def decode_step(self, params, tokens, caches, pos):
        """One token: tokens [B, 1] ids; pos scalar int32 (abs position).
        Returns (logits [B, V], new caches)."""
        cfg, r = self.cfg, self.rules
        params = self._maybe_cast_params(params)
        h = L.embed(tokens, params["embed"], self.compute_dtype)
        b = h.shape[0]
        h = r.constrain(h, r.act_btd(b))
        mrope_pos = None
        if cfg.mrope:
            p3 = jnp.broadcast_to(pos.astype(jnp.int32), (b, 1, 3))
            mrope_pos = p3
        h, _, new_caches = self.apply_layers(
            params, h, mode="decode", caches=caches, positions=pos,
            mrope_pos=mrope_pos)
        logits = self._logits(params, h)
        return logits[:, 0], new_caches

    # ----- caches -----

    def cache_window(self, cache_len: int) -> int:
        """Physical cache length: sliding window bounds it if set."""
        cfg = self.cfg
        if cfg.sliding_window and cfg.sliding_window < cache_len:
            return cfg.sliding_window
        return cache_len

    def _attn_cache_leaf(self, batch: int, w: int):
        cfg = self.cfg
        return {
            "k": (batch, w, cfg.num_kv_heads, cfg.head_dim),
            "v": (batch, w, cfg.num_kv_heads, cfg.head_dim),
            "slot_pos": (w,),
        }

    def _ssm_cache_leaf(self, batch: int):
        s = self.cfg.ssm
        di = s.expand * self.cfg.d_model
        nh = di // s.head_dim
        gn = s.ngroups * s.state_dim
        return {
            "ssm": (batch, nh, s.head_dim, s.state_dim),
            "conv_x": (batch, s.conv_width - 1, di),
            "conv_B": (batch, s.conv_width - 1, gn),
            "conv_C": (batch, s.conv_width - 1, gn),
        }

    def cache_shapes(self, batch: int, cache_len: int):
        """Shapes pytree (tuples) for the decode cache."""
        cfg = self.cfg
        w = self.cache_window(cache_len)
        ln = cfg.num_layers
        stack = lambda d: {k: (ln,) + v for k, v in d.items()}
        if cfg.arch_type == "ssm":
            return stack(self._ssm_cache_leaf(batch))
        if cfg.arch_type == "hybrid":
            n_inv = -(-ln // cfg.shared_attention_every)
            attn_leaf = self._attn_cache_leaf(batch, w)
            return (stack(self._ssm_cache_leaf(batch)),
                    {k: (n_inv,) + v for k, v in attn_leaf.items()})
        return stack(self._attn_cache_leaf(batch, w))

    def cache_specs(self, batch: int, cache_len: int):
        """PartitionSpec pytree congruent with cache_shapes."""
        cfg, r = self.cfg, self.rules
        dp = r.dp(batch)
        tp = r.model_axis
        cax = r.cache_axes
        attn_spec = {"k": P(None, dp, cax, None, None),
                     "v": P(None, dp, cax, None, None),
                     "slot_pos": P(None, cax)}
        ssm_spec = {"ssm": P(None, dp, tp, None, None),
                    "conv_x": P(None, dp, None, tp),
                    "conv_B": P(None, dp, None, None),
                    "conv_C": P(None, dp, None, None)}
        if cfg.arch_type == "ssm":
            return ssm_spec
        if cfg.arch_type == "hybrid":
            return (ssm_spec, attn_spec)
        return attn_spec

    def cache_dtypes(self, batch: int, cache_len: int):
        cdt = self.compute_dtype
        def leaf_dtype(name):
            if name == "slot_pos":
                return jnp.int32
            if name == "ssm":
                return jnp.float32
            return cdt
        shapes = self.cache_shapes(batch, cache_len)
        return jax.tree.map_with_path(
            lambda path, shape: leaf_dtype(path[-1].key), shapes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(i, int) for i in x))

    def init_cache(self, batch: int, cache_len: int):
        shapes = self.cache_shapes(batch, cache_len)
        dtypes = self.cache_dtypes(batch, cache_len)

        def mk(shape, dt):
            if dt == jnp.int32:
                return jnp.full(shape, -1, jnp.int32)
            return jnp.zeros(shape, dt)

        return jax.tree.map(
            mk, shapes, dtypes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(i, int) for i in x))
