"""Grouped-query attention with chunked online softmax (flash-style in pure
jnp — this is what the distributed path lowers; the Pallas kernel in
``repro.kernels.flash_attention`` is the TPU target validated against the same
oracle).

Supports:
  * full causal attention (train / prefill) without materializing S x S —
    query-chunked scan with an online-softmax inner scan over KV chunks;
  * sliding-window attention (Mixtral / Zamba2 shared block);
  * single-token decode against a KV cache with per-slot absolute positions
    (one layout for both full and rolling/sliding-window caches);
  * M-RoPE 3-D positions (Qwen2-VL).

Conventions:
  q: [B, S, Hq, Dh]; k/v: [B, S, Hkv, Dh], Hq = G * Hkv (GQA groups G).
  KV cache per layer: {"k": [B, W, Hkv, Dh], "v": same,
                       "slot_pos": [W] int32 absolute position per slot
                       (-1 = empty)}, where W = max_len (full) or window (SWA).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _group_query(q: jax.Array, num_kv_heads: int) -> jax.Array:
    """[B, S, Hq, Dh] -> [B, S, Hkv, G, Dh] without copying kv."""
    b, s, hq, dh = q.shape
    g = hq // num_kv_heads
    return q.reshape(b, s, num_kv_heads, g, dh)


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, window: int) -> jax.Array:
    """Additive mask bias [Sq, Sk]: 0 where attendable, NEG_INF otherwise.

    Causal (q_pos >= k_pos), optional sliding window (k_pos > q_pos - window),
    and k slot validity (k_pos >= 0, used for cache slots).
    """
    ok = (q_pos[:, None] >= k_pos[None, :]) & (k_pos[None, :] >= 0)
    if window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    window: int = 0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
    skip_masked_blocks: bool = True,
) -> jax.Array:
    """Causal GQA attention via chunked online softmax.

    q: [B, Sq, Hq, Dh]; k/v: [B, Sk, Hkv, Dh]; q_pos: [Sq]; k_pos: [Sk].
    Returns [B, Sq, Hq, Dh].  Peak memory ~ B * Hq * q_chunk * k_chunk.

    ``skip_masked_blocks``: wrap the inner block computation in a
    ``lax.cond`` keyed on block-level reachability (causality + window), so
    fully-masked KV blocks skip the two matmuls at runtime.  For causal
    attention this halves effective FLOPs; for sliding-window prefill it makes
    cost O(S*window) instead of O(S^2).
    """
    b, sq, hq, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)

    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    # pad to multiples (assigned shapes are powers of two; this is for tests)
    nq = -(-sq // q_chunk)
    nk = -(-sk // k_chunk)
    pad_q = nq * q_chunk - sq
    pad_k = nk * k_chunk - sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=-(10 ** 9))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad_k), constant_values=-1)

    qg = _group_query(q, hkv)  # [B, Sq, Hkv, G, Dh]
    qg = qg.reshape(b, nq, q_chunk, hkv, g, dh)
    kc = k.reshape(b, nk, k_chunk, hkv, dh)
    vc = v.reshape(b, nk, k_chunk, hkv, dh)
    qp = q_pos.reshape(nq, q_chunk)
    kp = k_pos.reshape(nk, k_chunk)

    def q_block(qi, q_blk, qp_blk):
        # online softmax over kv chunks
        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dh), jnp.float32)
        q_blk32 = q_blk.astype(jnp.float32)

        qp_max = jnp.max(qp_blk)
        qp_min = jnp.min(jnp.where(qp_blk < -(10 ** 8), qp_max, qp_blk))

        def kv_step(carry, inp):
            m, l, acc = carry
            k_blk, v_blk, kp_blk = inp

            def compute(_):
                s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk32,
                               k_blk.astype(jnp.float32)) * scale
                s = s + _mask_bias(qp_blk, kp_blk, window)[None, None, None]
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
                return m_new, l_new, acc_new

            if skip_masked_blocks:
                kp_min = jnp.min(jnp.where(kp_blk < 0, 10 ** 9, kp_blk))
                kp_max = jnp.max(kp_blk)
                reachable = kp_min <= qp_max  # some k is causally visible
                if window > 0:
                    reachable &= kp_max > (qp_min - window)
                m2, l2, a2 = jax.lax.cond(
                    reachable, compute, lambda _: (m, l, acc), operand=None)
            else:
                m2, l2, a2 = compute(None)
            return (m2, l2, a2), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # [B, Hkv, G, qc, Dh] -> [B, qc, Hkv*G, Dh]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, hq, dh)

    outs = jax.lax.map(
        lambda i: q_block(i, qg[:, i], qp[i]), jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, hq, dh)
    return out[:, :sq].astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    slot_pos: jax.Array,
    q_abs_pos: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    """One-token decode: q [B, 1, Hq, Dh] against cache [B, W, Hkv, Dh].

    slot_pos: [W] absolute positions per slot (-1 empty); q_abs_pos: scalar.
    """
    b, _, hq, dh = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)

    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg,
                   k_cache.astype(jnp.float32)) * scale
    ok = (slot_pos >= 0) & (slot_pos <= q_abs_pos)
    if window > 0:
        ok &= slot_pos > (q_abs_pos - window)
    s = s + jnp.where(ok, 0.0, NEG_INF)[None, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Cache helpers
# ---------------------------------------------------------------------------

def init_cache(batch: int, max_len: int, num_kv_heads: int, head_dim: int,
               dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
        "slot_pos": jnp.full((max_len,), -1, jnp.int32),
    }


def cache_prefill(cache: dict, k: jax.Array, v: jax.Array,
                  positions: jax.Array) -> dict:
    """Write a full prefill [B, S, ...] into the cache.

    For a rolling (window) cache with S > W, keeps the last W entries.
    """
    w = cache["k"].shape[1]
    s = k.shape[1]
    if s >= w:
        k_in, v_in, p_in = k[:, -w:], v[:, -w:], positions[-w:]
        slots = p_in % w
    else:
        k_in, v_in, p_in = k, v, positions
        slots = positions % w
    new_k = cache["k"].at[:, slots].set(k_in.astype(cache["k"].dtype))
    new_v = cache["v"].at[:, slots].set(v_in.astype(cache["v"].dtype))
    new_pos = cache["slot_pos"].at[slots].set(p_in.astype(jnp.int32))
    return {"k": new_k, "v": new_v, "slot_pos": new_pos}


def cache_append(cache: dict, k: jax.Array, v: jax.Array,
                 pos: jax.Array) -> dict:
    """Append one token (k/v: [B, 1, Hkv, Dh]) at absolute position ``pos``."""
    w = cache["k"].shape[1]
    slot = pos % w
    new_k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    new_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], pos[None].astype(jnp.int32), slot, axis=0)
    return {"k": new_k, "v": new_v, "slot_pos": new_pos}
