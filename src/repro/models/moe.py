"""Mixture-of-experts block.

Dispatch is the sort-based "dropping" scheme (Switch-style capacity): tokens
are argsorted by assigned expert, positions within each expert group beyond
``capacity`` are dropped, experts run as one batched einsum, and results are
combined with the renormalized top-k router weights.  This keeps compiled
FLOPs proportional to *active* parameters (times the capacity factor) — a
dense all-experts formulation would inflate the roofline by E/k.

The block is a ``shard_map`` island inside the jitted step so the collective
pattern is explicit and auditable in the dry-run HLO:

  * ``tp`` sharding: every model-rank holds all experts with the FFN hidden
    dim sharded ``F/tp``; one ``psum`` over the model axis after combine.
  * ``ep`` sharding: experts sharded ``E/tp`` over the model axis; token
    activations are replicated over the model axis (they are sharded over
    data/pod only), so each rank dispatches the *same* local tokens to its
    own experts and the partial combines are ``psum``-reduced.  No all-to-all
    is needed because token-parallel and expert-parallel axes are orthogonal.

Both variants produce identical math (tested); they differ only in collective
schedule and per-rank matmul shapes — exactly the knob §Perf hillclimbs.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import MoEConfig


def router_topk(x32: jax.Array, router_w: jax.Array, k: int):
    """Top-k routing with renormalized gates.  x32: [T, D] fp32."""
    logits = x32 @ router_w.astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # [T, k]
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    return probs, gate, idx


def load_balance_aux(probs: jax.Array, idx: jax.Array, num_experts: int):
    """Switch-style load-balance loss: E * sum_e f_e * p_e."""
    t = probs.shape[0]
    onehot = jax.nn.one_hot(idx[:, 0], num_experts, dtype=jnp.float32)
    f = jnp.mean(onehot, axis=0)          # fraction of tokens (1st choice)
    p = jnp.mean(probs, axis=0)           # mean router prob
    del t
    return num_experts * jnp.sum(f * p)


def _dispatch_indices(idx: jax.Array, tokens: int, num_experts: int,
                      capacity: int):
    """Sort-based dispatch bookkeeping.

    Returns (sorted_expert, sorted_token, sorted_slot_in_expert, keep_mask),
    all [T*k].
    """
    k = idx.shape[1]
    flat_e = idx.reshape(-1)                                   # [T*k]
    tok_id = jnp.repeat(jnp.arange(tokens, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    st = tok_id[order]
    group_start = jnp.searchsorted(se, jnp.arange(num_experts), side="left")
    pos = jnp.arange(tokens * k, dtype=jnp.int32) - group_start[se]
    keep = pos < capacity
    return order, se, st, pos, keep


def _expert_ffn(xe: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                w_down: jax.Array) -> jax.Array:
    """Batched per-expert SwiGLU: xe [E, C, D] -> [E, C, D] (partial if the
    hidden dim is sharded — caller psums)."""
    dt = xe.dtype
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", xe, w_up.astype(dt))
    return jnp.einsum("ecf,efd->ecd", g * u, w_down.astype(dt))


def capacity_for(tokens: int, moe: MoEConfig) -> int:
    return max(1, math.ceil(tokens * moe.experts_per_token
                            / moe.num_experts * moe.capacity_factor))


def moe_block_local(x: jax.Array, router_w: jax.Array, w_gate: jax.Array,
                    w_up: jax.Array, w_down: jax.Array, *, moe: MoEConfig,
                    model_axis: str, data_axes: tuple[str, ...],
                    moe_sharding: str = "tp",
                    reduce_axes: tuple[str, ...] = ()):
    """Per-shard MoE block body (runs inside shard_map).

    x: [T_local, D] (tokens local to the data shard, replicated over model).
    Weights: tp -> [E, D, F/tp]; ep -> [E/tp, D, F].
    ``reduce_axes``: axes the hidden dim is sharded over in tp mode
    (default just the model axis; the serving layout adds the data axes).
    Returns (y [T_local, D] fully reduced, aux loss scalar replicated).
    """
    reduce_axes = reduce_axes or (model_axis,)
    t, d = x.shape
    e, k = moe.num_experts, moe.experts_per_token
    cap = capacity_for(t, moe)

    x32 = x.astype(jnp.float32)
    probs, gate, idx = router_topk(x32, router_w, k)
    aux = load_balance_aux(probs, idx, e)
    aux = jax.lax.pmean(aux, data_axes)

    order, se, st, pos, keep = _dispatch_indices(idx, t, e, cap)
    sg = gate.reshape(-1)[order]

    if moe_sharding == "ep":
        n_shards = compat.axis_size(model_axis)
        rank = jax.lax.axis_index(model_axis)
        e_loc = e // n_shards
        off = rank * e_loc
        local = keep & (se >= off) & (se < off + e_loc)
        dest = jnp.where(local, (se - off) * cap + pos, e_loc * cap)  # OOB=drop
        rows = e_loc * cap
    else:
        local = keep
        dest = jnp.where(local, se * cap + pos, e * cap)
        rows = e * cap

    # scatter tokens into expert buffers ([rows, D]); OOB indices drop
    gathered = jnp.where(local[:, None], x[st], 0)
    xe = jnp.zeros((rows, d), x.dtype).at[dest].add(
        gathered, mode="drop")
    xe = xe.reshape(-1, cap, d)

    ye = _expert_ffn(xe, w_gate, w_up, w_down).reshape(rows, d)

    # combine with gates back to token order (partial: hidden-shard for tp,
    # expert-shard for ep), then reduce over the model axis.
    contrib = jnp.where(local[:, None], sg[:, None].astype(ye.dtype)
                        * ye.at[dest, :].get(mode="fill", fill_value=0), 0)
    y = jnp.zeros((t, d), ye.dtype).at[st].add(contrib)
    y = jax.lax.psum(y, model_axis if moe_sharding == "ep" else reduce_axes)
    return y.astype(x.dtype), aux


def make_sharded_moe(mesh, *, moe: MoEConfig, model_axis: str = "model",
                     data_axes: tuple[str, ...] = ("data",),
                     moe_sharding: str = "tp", batch_spec="__default__",
                     feature_axes: tuple[str, ...] = ()):
    """Wrap the local block in shard_map for the given mesh.

    Token arrays come in as [B, S, D] sharded over data axes on batch; the
    wrapper flattens to local tokens.  ``batch_spec`` overrides the batch-dim
    sharding (None when the global batch doesn't divide the data axes, e.g.
    long_500k's batch of 1 — tokens then replicate across data shards).
    Expert weights: see moe_block_local.
    """
    from jax.sharding import PartitionSpec as P

    if batch_spec == "__default__":
        batch_spec = data_axes
    feature_axes = feature_axes or (model_axis,)

    if moe_sharding == "ep":
        wspec = P(model_axis, None, None)
        wspec_down = P(model_axis, None, None)
    else:
        wspec = P(None, None, feature_axes)
        wspec_down = P(None, feature_axes, None)

    body = partial(moe_block_local, moe=moe, model_axis=model_axis,
                   data_axes=data_axes, moe_sharding=moe_sharding,
                   reduce_axes=feature_axes)

    def flat_body(xbsd, rw, wg, wu, wd):
        b, s, d = xbsd.shape
        y, aux = body(xbsd.reshape(b * s, d), rw, wg, wu, wd)
        return y.reshape(b, s, d), aux

    return compat.shard_map(
        flat_body,
        mesh=mesh,
        in_specs=(P(batch_spec, None, None), P(None, None),
                  wspec, wspec, wspec_down),
        out_specs=(P(batch_spec, None, None), P()),
        check_vma=False,
    )
