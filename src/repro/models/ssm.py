"""Mamba2 (SSD — state space duality, arXiv:2405.21060) block in pure JAX.

The chunked SSD algorithm: within a chunk the recurrence is computed as a
(masked, decay-weighted) quadratic attention-like product; across chunks a
small ``lax.scan`` carries the [H, P, N] state.  This is the formulation the
distributed path lowers; ``repro.kernels.ssd`` is the Pallas TPU kernel for
the intra-chunk part, validated against ``ssd_reference`` (naive recurrence).

Shapes:
  x   [B, S, H, P]   (P = head_dim)
  dt  [B, S, H]      (post softplus, > 0)
  A   [H]            (negative reals: -exp(A_log))
  B,C [B, S, G, N]   (G groups share B/C across H//G heads)
  state [B, H, P, N]
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def segsum(dA: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} dA[..., k], i >= j.

    dA: [..., L]; returns [..., L, L] (lower-triangular; -inf above diag).
    """
    l = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_{j+1..i} when i>=j
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, D: jax.Array, *, chunk: int,
                init_state: Optional[jax.Array] = None,
                return_state: bool = False,
                compute_dtype=jnp.float32):
    """Chunked SSD scan.  Returns y [B, S, H, P] (and final state).

    ``compute_dtype``: dtype for the intra-chunk einsums (§Perf knob —
    bf16 halves the dominant [L, L] intermediate traffic; the decay
    cumsum/exp and the inter-chunk state stay fp32).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // chunk

    f32 = jnp.float32
    cd = compute_dtype
    xc = x.reshape(b, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, nc, chunk, h).astype(f32)
    Bc = B.reshape(b, nc, chunk, g, n).astype(f32)
    Cc = C.reshape(b, nc, chunk, g, n).astype(f32)
    A32 = A.astype(f32)

    dA = dtc * A32  # [b, nc, l, h]
    cs = jnp.cumsum(dA, axis=2)  # within-chunk cumulative
    xdt = xc * dtc[..., None]  # [b, nc, l, h, p]

    # ---- intra-chunk (diagonal blocks) ----
    # scores[b,c,i,j,g] = C_i . B_j ; decay via segsum of dA per head
    scores = jnp.einsum("bcign,bcjgn->bcijg", Cc.astype(cd), Bc.astype(cd))
    L = jnp.exp(segsum(dA.transpose(0, 1, 3, 2)))  # [b, nc, h, i, j]
    Lh = L.reshape(b, nc, g, hg, chunk, chunk)
    y_diag = jnp.einsum("bcijg,bcghij,bcjghp->bcighp",
                        scores.astype(cd), Lh.astype(cd),
                        xdt.reshape(b, nc, chunk, g, hg, p).astype(cd))
    y_diag = y_diag.reshape(b, nc, chunk, h, p).astype(f32)

    # ---- per-chunk end states ----
    # decay from step j to end of chunk: exp(cs_last - cs_j)
    dec_end = jnp.exp(cs[:, :, -1:, :] - cs)  # [b, nc, l, h]
    states = jnp.einsum("bclgn,bclgh,bclghp->bcghpn",
                        Bc,
                        dec_end.reshape(b, nc, chunk, g, hg),
                        xdt.reshape(b, nc, chunk, g, hg, p))
    states = states.reshape(b, nc, h, p, n)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))  # [b, nc, h]
    s0 = (init_state.astype(f32) if init_state is not None
          else jnp.zeros((b, h, p, n), f32))

    def step(carry, inp):
        st_in, dec = inp  # [b,h,p,n], [b,h]
        prev = carry
        new = prev * dec[..., None, None] + st_in
        return new, prev

    final, prev_states = jax.lax.scan(
        step, s0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)  # [b, nc, h, p, n]

    # ---- inter-chunk contribution ----
    dec_in = jnp.exp(cs)  # decay from chunk start to step i
    y_off = jnp.einsum(
        "bcign,bcghpn,bcigh->bcighp",
        Cc,
        prev_states.reshape(b, nc, g, hg, p, n),
        dec_in.reshape(b, nc, chunk, g, hg))
    y_off = y_off.reshape(b, nc, chunk, h, p)

    y = (y_diag + y_off).reshape(b, nc * chunk, h, p)[:, :s]
    y = y + x[:, :s].astype(f32) * D.astype(f32)[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, final
    return y


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    A: jax.Array, B: jax.Array, C: jax.Array, D: jax.Array):
    """Single-token recurrent update.

    state [B, H, P, N]; x [B, H, P]; dt [B, H]; B/C [B, G, N].
    Returns (y [B, H, P], new_state).
    """
    b, h, p = x.shape
    g, n = B.shape[1], B.shape[2]
    hg = h // g
    f32 = jnp.float32
    dA = jnp.exp(dt.astype(f32) * A.astype(f32))  # [B, H]
    xdt = x.astype(f32) * dt.astype(f32)[..., None]  # [B, H, P]
    upd = jnp.einsum("bgn,bghp->bghpn",
                     B.astype(f32),
                     xdt.reshape(b, g, hg, p)).reshape(b, h, p, n)
    new_state = state.astype(f32) * dA[..., None, None] + upd
    y = jnp.einsum("bgn,bghpn->bghp", C.astype(f32),
                   new_state.reshape(b, g, hg, p, n)).reshape(b, h, p)
    y = y + x.astype(f32) * D.astype(f32)[None, :, None]
    return y.astype(x.dtype), new_state.astype(state.dtype)


def ssd_reference(x, dt, A, B, C, D, init_state=None):
    """Naive step-by-step recurrence oracle (fp32)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    state = (init_state.astype(jnp.float32) if init_state is not None
             else jnp.zeros((b, h, p, n), jnp.float32))
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(
            state, x[:, t].astype(jnp.float32), dt[:, t], A,
            B[:, t], C[:, t], D)
        ys.append(y)
    return jnp.stack(ys, axis=1).astype(x.dtype), state


# ---------------------------------------------------------------------------
# Depthwise causal conv (width w) over the sequence
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                init_state: Optional[jax.Array] = None):
    """x [B, S, Ch]; w [W, Ch]; b [Ch].  Returns (y [B, S, Ch], tail state).

    ``init_state`` is the previous (W-1) inputs [B, W-1, Ch] (decode/prefill
    continuation); the returned state is the last (W-1) inputs.
    """
    width = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(width))
    y = y + b.astype(x.dtype)
    tail = xp[:, -(width - 1):] if width > 1 else init_state
    return jax.nn.silu(y), tail


def conv_decode_step(conv_state: jax.Array, x: jax.Array, w: jax.Array,
                     b: jax.Array):
    """One-token conv update.  conv_state [B, W-1, Ch]; x [B, Ch]."""
    width = w.shape[0]
    full = jnp.concatenate([conv_state, x[:, None]], axis=1)  # [B, W, Ch]
    y = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    new_state = full[:, 1:] if width > 1 else conv_state
    return jax.nn.silu(y).astype(x.dtype), new_state.astype(conv_state.dtype)
