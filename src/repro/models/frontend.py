"""Modality frontend stubs (the one sanctioned carve-out).

``musicgen-large`` consumes EnCodec frame embeddings; ``qwen2-vl-72b``
consumes ViT patch embeddings + 3-D M-RoPE position ids.  The frontends
themselves (conv codec / vision tower) are NOT implemented — these helpers
produce correctly-shaped stand-ins (ShapeDtypeStructs for the dry-run,
random arrays for smoke tests), and the language/decoder backbone that
consumes them is fully implemented.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def needs_embeddings(cfg: ModelConfig) -> bool:
    return cfg.arch_type in ("vlm", "audio")


def embedding_spec(cfg: ModelConfig, batch: int, seq: int,
                   dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dtype)


def mrope_pos_spec(cfg: ModelConfig, batch: int,
                   seq: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, seq, 3), jnp.int32)


def fake_embeddings(cfg: ModelConfig, batch: int, seq: int, key=None):
    key = key if key is not None else jax.random.key(0)
    return jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32)


def fake_mrope_pos(cfg: ModelConfig, batch: int, seq: int):
    """Text-like default: all three streams share the token index."""
    pos = jnp.arange(seq, dtype=jnp.int32)
    return jnp.broadcast_to(pos[None, :, None], (batch, seq, 3))
