"""Kernel entry points in the model's tensor layout, with launch
configs resolved from the ambient :class:`~repro.tune.profile.TuningProfile`.

The distributed (sharded) path lowers the pure-jnp implementations in
``repro.models``; these ops are the TPU-target kernel entry points, used
by the kernel benchmarks and validated in interpret mode on CPU.

Config resolution (must happen OUTSIDE jit — the blocks are static
launch parameters): caller-supplied kwargs win; otherwise the ambient
profile (installed at boot by the bootseer ``tune.restore`` task) is
consulted per ``(kernel, shape-bucket, dtype, backend)``; otherwise the
hardcoded defaults.  A profile with ``tune_on_miss`` set tunes an
unseen key once on first use and publishes the updated profile back
through its store (record-on-miss).

When Pallas cannot run (CPU backend without ``interpret=True``) the ops
fall back to the ``repro.kernels.ref`` oracles — and since the
reference path has no launch configs, any caller-supplied config kwargs
are being DROPPED: that emits a one-time ``RuntimeWarning`` and bumps
``stats["ref_fallbacks"]`` / ``stats["dropped_configs"]`` (mirrored
into the active profile's stats), so autotune measurements can never be
silently attributed to the wrong implementation.
"""

from __future__ import annotations

import threading
import warnings

import jax

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_reference, ssd_reference
from repro.kernels.ssd import ssd_chunked_kernel
from repro.tune.profile import (attention_key, get_active_profile,
                                ssd_key)

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
DEFAULT_CHUNK = 256

stats = {"ref_fallbacks": 0, "dropped_configs": 0,
         "profile_hits": 0, "profile_misses": 0, "miss_tunes": 0}

_warn_lock = threading.Lock()
_warned: set = set()


def _warn_once(tag: str, msg: str) -> None:
    with _warn_lock:
        if tag in _warned:
            return
        _warned.add(tag)
    warnings.warn(msg, RuntimeWarning, stacklevel=3)


def _pallas_available(interpret: bool) -> bool:
    return bool(interpret) or jax.default_backend() == "tpu"


def _record_fallback(kernel: str, supplied: dict) -> None:
    dropped = {k: v for k, v in supplied.items() if v is not None}
    stats["ref_fallbacks"] += 1
    prof = get_active_profile()
    if prof is not None:
        prof.note("ref_fallbacks")
    if dropped:
        stats["dropped_configs"] += 1
        if prof is not None:
            prof.note("dropped_configs")
        _warn_once(
            f"{kernel}.dropped_config",
            f"{kernel}: falling back to the reference path (backend "
            f"{jax.default_backend()!r}, interpret=False) — the supplied "
            f"launch config {dropped} is DROPPED and the result is NOT "
            "a Pallas measurement (pass interpret=True to exercise the "
            "kernel on CPU)")
    else:
        _warn_once(
            f"{kernel}.ref_fallback",
            f"{kernel}: Pallas unavailable (backend "
            f"{jax.default_backend()!r}, interpret=False); using the "
            "reference implementation")


def _resolve(kernel: str, key: str, supplied: dict, defaults: dict,
             tune_kwargs: dict) -> dict:
    """Launch config for ``key``: supplied kwargs > ambient profile >
    defaults (field-wise: a caller may pin block_q and let the profile
    pick block_k)."""
    cfg = dict(defaults)
    prof = get_active_profile()
    if prof is not None:
        hit = prof.resolve(key)
        if hit is None and prof.tune_on_miss:
            from repro.tune import autotune
            stats["miss_tunes"] += 1
            try:
                _, entry = autotune.tune_workload(
                    dict(kernel=kernel, **tune_kwargs),
                    backend=prof.backend, profile=prof)
                hit = dict(entry["config"])
                if prof.store is not None:
                    prof.store.publish(prof)
            except Exception as e:  # noqa: BLE001 - tuning must not
                _warn_once(f"{kernel}.miss_tune",  # break a forward pass
                           f"{kernel}: record-on-miss tuning failed "
                           f"({e!r}); using defaults")
        if hit is None:
            stats["profile_misses"] += 1
        else:
            stats["profile_hits"] += 1
            cfg.update({k: v for k, v in hit.items() if k in defaults})
    cfg.update({k: v for k, v in supplied.items() if v is not None})
    return cfg


def attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                 block_q: int | None = None, block_k: int | None = None,
                 interpret: bool = False):
    """Model layout: q [B, S, Hq, Dh], k/v [B, S, Hkv, Dh] ->
    [B, S, Hq, Dh]."""
    supplied = {"block_q": block_q, "block_k": block_k}
    if not _pallas_available(interpret):
        _record_fallback("flash_attention", supplied)
        qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        out = attention_reference(qt, kt, vt, causal=causal,
                                  window=window)
        return out.transpose(0, 2, 1, 3)
    b, sq, hq, d = q.shape
    hkv, sk = k.shape[2], k.shape[1]
    prof = get_active_profile()
    backend = prof.backend if prof is not None else "cpu-interpret"
    key = attention_key(sq=sq, sk=sk, d=d, g=hq // max(hkv, 1),
                        dtype=str(q.dtype), causal=causal,
                        window=window, backend=backend)
    cfg = _resolve(
        "flash_attention", key, supplied,
        {"block_q": DEFAULT_BLOCK_Q, "block_k": DEFAULT_BLOCK_K},
        dict(b=b, hq=hq, hkv=hkv, sq=sq, sk=sk, d=d,
             dtype=str(q.dtype), causal=causal, window=window,
             interpret=interpret))
    qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          block_q=cfg["block_q"], block_k=cfg["block_k"],
                          interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def ssd_op(x, dt, A, B, C, D, *, chunk: int | None = None,
           interpret: bool = False):
    """Model layout (see repro.models.ssm).  Returns (y, final_state)."""
    supplied = {"chunk": chunk}
    if not _pallas_available(interpret):
        _record_fallback("ssd", supplied)
        return ssd_reference(x, dt, A, B, C, D)
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    prof = get_active_profile()
    backend = prof.backend if prof is not None else "cpu-interpret"
    key = ssd_key(s=s, h=h, p=p, g=g, n=n, dtype=str(x.dtype),
                  backend=backend)
    cfg = _resolve("ssd", key, supplied, {"chunk": DEFAULT_CHUNK},
                   dict(b=b, s=s, h=h, p=p, g=g, n=n,
                        dtype=str(x.dtype), interpret=interpret))
    return ssd_chunked_kernel(x, dt, A, B, C, D, chunk=cfg["chunk"],
                              interpret=interpret)
