"""jit'd wrappers exposing the Pallas kernels in the model's tensor layout.

The distributed (sharded) path lowers the pure-jnp implementations in
``repro.models``; these ops are the TPU-target kernel entry points, used by
the kernel benchmarks and validated in interpret mode on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd import ssd_chunked_kernel


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                 interpret: bool = False):
    """Model layout: q [B, S, Hq, Dh], k/v [B, S, Hkv, Dh] ->
    [B, S, Hq, Dh]."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          interpret=interpret)
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_op(x, dt, A, B, C, D, *, chunk: int = 256,
           interpret: bool = False):
    """Model layout (see repro.models.ssm).  Returns (y, final_state)."""
    return ssd_chunked_kernel(x, dt, A, B, C, D, chunk=chunk,
                              interpret=interpret)
