"""Pallas TPU kernel for the Mamba2 SSD chunked scan (arXiv:2405.21060).

TPU adaptation (DESIGN.md §2): the chunk dimension is the innermost grid
axis, so the [P, N] inter-chunk state lives in VMEM scratch and is carried
sequentially across chunk iterations — the TPU-native replacement for the
GPU kernel's warp-level state exchange.  Within a chunk the computation is
three MXU matmuls (C@B^T, P@x, x^T@B) over [L, N]/[L, P] tiles with the
decay factors applied as VPU elementwise ops.

Grid: (B*H, n_chunks).  B/C are shared across head groups via the BlockSpec
index map (no materialized repeat).

Validated on CPU (interpret mode) against the naive recurrence oracle
``repro.kernels.ref.ssd_reference``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, st_ref,
                state_scr, *, chunk: int, seq_len: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)        # [L, P]
    dt = dt_ref[0].astype(jnp.float32)      # [L, 1]
    # EXACT pad masking (the same discipline flash_attention applies
    # with its `kpos < seq_k` mask): zero dt at padded positions, so a
    # padded step contributes nothing to the intra-chunk quadratic
    # (pmat's column weight is dt_j), nothing to the state update
    # (w ~ dt), and leaves the cumulative decay flat — the carried
    # state and final_state come out bit-identical to the unpadded
    # recurrence for ANY chunk the tuner may pick, instead of drifting
    # by an epsilon that scales with the pad count.
    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    dt = jnp.where(pos < seq_len, dt, 0.0)
    a = a_ref[0, 0]                          # scalar (negative)
    bm = b_ref[0].astype(jnp.float32)       # [L, N]
    cm = c_ref[0].astype(jnp.float32)       # [L, N]
    dD = d_ref[0, 0]                         # scalar

    dA = dt * a                              # [L, 1]
    cs = jnp.cumsum(dA, axis=0)              # [L, 1]

    # ---- intra-chunk (masked decay-weighted quadratic) ----
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # [L, L] = C_i . B_j
    li = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    decay = jnp.where(li >= lj, jnp.exp(cs - cs.reshape(1, -1)), 0.0)
    pmat = scores * decay * dt.reshape(1, -1)  # weight column j by dt_j
    y = jax.lax.dot_general(pmat, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, P]

    # ---- inter-chunk contribution from the carried state ----
    st = state_scr[...]                      # [P, N]
    y += jax.lax.dot_general(
        cm, st, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(cs)

    # ---- skip connection ----
    y += x * dD
    y_ref[0] = y.astype(y_ref.dtype)

    # ---- state update ----
    cs_last = cs[chunk - 1]                  # [1]
    w = jnp.exp(cs_last[None, :] - cs) * dt  # [L, 1]
    st_add = jax.lax.dot_general(
        x * w, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # [P, N]
    state_scr[...] = st * jnp.exp(cs_last[0]) + st_add

    @pl.when(ci == nc - 1)
    def _emit_state():
        st_ref[0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_kernel(x, dt, A, B, C, D, *, chunk: int = 256,
                       interpret: bool = False):
    """x [B, S, H, P]; dt [B, S, H] (>0); A [H] (<0); B/C [B, S, G, N];
    D [H].  Returns (y [B, S, H, P], final_state [B, H, P, N])."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    # clamp like flash_attention clamps block_q/block_k: a tuned config
    # from a larger shape-bucket (or a corrupt profile's nonsense value)
    # must degrade to a legal launch, never break a short-sequence call
    chunk = max(1, min(chunk, s))
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad value is irrelevant: the kernel hard-masks dt by position
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk

    xf = x.transpose(0, 2, 1, 3).reshape(b * h, sp, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, sp, 1)
    bf = B.transpose(0, 2, 1, 3).reshape(b * g, sp, n)
    cf = C.transpose(0, 2, 1, 3).reshape(b * g, sp, n)
    af = A.reshape(h, 1).astype(jnp.float32)
    df = D.reshape(h, 1).astype(jnp.float32)

    def xmap(bh, ci):
        return (bh, ci, 0)

    def bcmap(bh, ci):
        bi, hi = bh // h, bh % h
        return (bi * g + hi // hg, ci, 0)

    def amap(bh, ci):
        return (bh % h, 0)

    def stmap(bh, ci):
        return (bh, 0, 0)

    y, st = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, seq_len=s),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), xmap),
            pl.BlockSpec((1, chunk, 1), xmap),
            pl.BlockSpec((1, 1), amap),
            pl.BlockSpec((1, chunk, n), bcmap),
            pl.BlockSpec((1, chunk, n), bcmap),
            pl.BlockSpec((1, 1), amap),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), xmap),
            pl.BlockSpec((1, p, n), stmap),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, sp, p), x.dtype),
            jax.ShapeDtypeStruct((b * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf, df)

    y = y.reshape(b, h, sp, p).transpose(0, 2, 1, 3)[:, :s]
    st = st.reshape(b, h, p, n)
    return y, st
