"""Pallas TPU flash-attention forward kernel.

TPU-native tiling: the grid is (batch*q_heads, Sq/block_q, Sk/block_k) with
the KV-block dimension innermost, so the online-softmax running state
(m, l, acc) lives in VMEM scratch across the inner iterations and the output
tile is written once on the last KV block.  Block shapes are MXU-aligned
(128 x head_dim).  GQA is handled in the BlockSpec index maps: the kv-head
index is derived from the q-head index (no materialized KV repeat).

Causal / sliding-window masking uses absolute-position iotas; fully-masked
KV blocks are skipped with ``pl.when`` (block-level early-out, the same
optimization the pure-jnp path applies with ``skip_masked_blocks``).

Target: TPU (MXU 128x128, VMEM tiles).  Validated on CPU in interpret mode
against ``repro.kernels.ref.attention_reference``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, seq_k: int,
                  causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * block_q
    k_lo = ki * block_k

    # block-level reachability (early-out for fully masked KV blocks)
    run = True
    if causal:
        run = jnp.logical_and(True, k_lo <= q_lo + block_q - 1)
    if window > 0:
        run = jnp.logical_and(run, k_lo + block_k - 1 > q_lo - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0].astype(jnp.float32)          # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = kpos < seq_k
        if causal:
            ok = jnp.logical_and(ok, qpos >= kpos)
        if window > 0:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                        # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "num_kv_heads", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    num_kv_heads: int | None = None, causal: bool = True,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Sk, D].  Returns [B, Hq, Sq, D]."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    if num_kv_heads is not None:
        assert hkv == num_kv_heads
    g = hq // hkv
    sk = k.shape[2]
    scale = 1.0 / math.sqrt(d)

    # clamp to the true lengths (and to >= 1, so a config tuned for a
    # larger shape-bucket or a garbage profile value stays launchable)
    block_q = max(1, min(block_q, sq))
    block_k = max(1, min(block_k, sk))
    # pad sequence dims to block multiples
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_k

    qf = q.reshape(b * hq, q.shape[2], d)
    kf = k.reshape(b * hkv, k.shape[2], d)
    vf = v.reshape(b * hkv, v.shape[2], d)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        # GQA: q-head bh = bi*hq + h -> kv row bi*hkv + h // g
        bi = bh // hq
        h = bh % hq
        return (bi * hkv + h // g, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_k=sk, causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, nq * block_q, d)[:, :, :sq]
