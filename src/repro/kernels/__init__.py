"""Pallas TPU kernels for the compute hot spots: flash attention and the
Mamba2 SSD chunk scan.  Each has a pure-jnp oracle in ``ref.py`` and a
model-layout wrapper in ``ops.py``; correctness is swept in
``tests/test_kernels.py`` (interpret mode on CPU)."""

from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.ssd import ssd_chunked_kernel  # noqa: F401
from repro.kernels.ops import attention_op, ssd_op  # noqa: F401
