"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """Naive softmax attention.  q: [B, Hq, Sq, D]; k/v: [B, Hkv, Sk, D]."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) / math.sqrt(d)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= qpos >= kpos
    if window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def ssd_reference(x, dt, A, B, C, D, init_state=None):
    """Naive Mamba2 recurrence.  See repro.models.ssm.ssd_reference."""
    from repro.models.ssm import ssd_reference as _ref
    return _ref(x, dt, A, B, C, D, init_state=init_state)
