"""NodeCache — the fabric's content-addressed, byte-bounded node cache.

Before the fabric existed, three layers each grew their own node-local
cache with no bound and their own singleflight: the blockstore block
cache (a bare directory of content-addressed files), the env-cache
archive cache (directory + per-key locks), and ad-hoc memoization in the
DFS readers.  ``NodeCache`` replaces all of them:

* **content-addressed** — a key names immutable bytes (block digest,
  archive digest).  Keys never change meaning, so admission races are
  benign: whoever publishes first wins and the loser's bytes are
  identical.
* **byte-bounded** — ``capacity_bytes`` caps the on-disk footprint;
  admission evicts victims chosen by a pluggable :class:`EvictionPolicy`
  (LRU by default, hot-block-score-aware via :class:`HotScorePolicy`).
* **singleflight admission** — ``fetch_path``/``get_or_fetch`` coalesce
  concurrent misses on one key into a single producer call per node.
* **per-job pinning** — a running restore pins its working set; pinned
  entries are never eviction victims, so cache pressure from a
  concurrent job cannot evict bytes a startup is actively replaying.
* **eviction listeners** — consumers that *advertise* cached content
  (the swarm availability index) register a listener and withdraw the
  block the moment it leaves disk, so no peer is ever routed to a block
  that is gone.

Files are published atomically (tmp + ``os.link``/``replace``), exactly
like the old blockstore cache, so a crash mid-write never leaves a
half-admitted entry — and the index is rebuilt from the directory on
construction, so warm restarts inherit the previous run's cache.
"""

from __future__ import annotations

import os
import threading
from collections import OrderedDict
from pathlib import Path
from typing import Callable, Dict, Iterator, Optional, Set, Tuple


class EvictionPolicy:
    """Victim-selection strategy.  The cache serializes all calls under
    its index lock, so implementations need no locking of their own."""

    def on_admit(self, key: str) -> None:
        raise NotImplementedError

    def on_access(self, key: str) -> None:
        raise NotImplementedError

    def on_remove(self, key: str) -> None:
        raise NotImplementedError

    def victims(self) -> Iterator[str]:
        """Keys in eviction order (best victim first)."""
        raise NotImplementedError


class LRUPolicy(EvictionPolicy):
    """Least-recently-used: the default, matching what an unbounded cache
    degenerates to when capacity is infinite."""

    def __init__(self):
        self._order: "OrderedDict[str, None]" = OrderedDict()

    def on_admit(self, key: str) -> None:
        self._order[key] = None
        self._order.move_to_end(key)

    def on_access(self, key: str) -> None:
        if key in self._order:
            self._order.move_to_end(key)

    def on_remove(self, key: str) -> None:
        self._order.pop(key, None)

    def victims(self) -> Iterator[str]:
        return iter(list(self._order))


class HotScorePolicy(LRUPolicy):
    """Hot-block-score-aware eviction: coldest score first, LRU within a
    score class.

    ``score_fn(key) -> float`` supplies the heat (wire it to
    ``HotBlockService.score_index`` so the image blocks a startup
    actually replays outlive cold-streamed filler); keys the service has
    never seen score 0.0 and go first.
    """

    def __init__(self, score_fn: Callable[[str], float]):
        super().__init__()
        self.score_fn = score_fn

    def victims(self) -> Iterator[str]:
        lru_rank = {k: i for i, k in enumerate(self._order)}
        return iter(sorted(
            lru_rank, key=lambda k: (self.score_fn(k), lru_rank[k])))


def _is_cache_entry(name: str) -> bool:
    return not name.startswith(".") and ".tmp" not in name


# ---------------------------------------------------------------------------
# range-addressed entries (restore-ahead prefetch)
# ---------------------------------------------------------------------------

def range_key(stream: str, offset: int, length: int) -> str:
    """Cache key naming one immutable byte range of an immutable stream.

    Checkpoint data files never change once written, so ``(stream id,
    offset, length)`` names immutable bytes exactly like a content
    digest does — admission races stay benign.  The stream id is folded
    through sha1 so arbitrary DFS paths become filename-safe keys.
    """
    import hashlib
    sid = hashlib.sha1(stream.encode()).hexdigest()[:16]
    return f"range.{sid}.{offset:x}.{length:x}"


class CachedRangeReader:
    """A ``pread_many`` reader that consults a :class:`NodeCache` of
    range-addressed entries before touching the wrapped reader.

    Restore-ahead prefetch (repro.core.bootseer) stores a checkpoint's
    wave-0 plan ranges under :func:`range_key`; a crash-restart's planned
    restore recomputes the SAME plan, so its reads key-match exactly and
    are served from node-local disk with zero DFS preads.  Ranges not in
    the cache fall through to the inner reader in one batched call.
    ``on_hit(nbytes)`` reports served bytes (the runtime wires it to the
    cluster-wide fabric accounting so ``StartupResult.notes`` can show
    per-run ``restore_ahead_hit_bytes``).
    """

    def __init__(self, inner, cache: "NodeCache", stream: str, *,
                 job: Optional[str] = None,
                 on_hit: Optional[Callable[[int], None]] = None):
        self.inner = inner
        self.cache = cache
        self.stream = stream
        self.job = job
        self.on_hit = on_hit
        self.cache_stats = {"hit_bytes": 0, "miss_bytes": 0,
                            "hits": 0, "misses": 0}

    @property
    def stats(self) -> dict:
        """The inner reader's fabric counters (reconstruction deltas flow
        through unchanged — cache hits never reconstruct anything)."""
        return getattr(self.inner, "stats", {})

    def pread(self, offset: int, length: int) -> bytes:
        return self.pread_many([(offset, length)])[0]

    def pread_many(self, ranges, into=None, priority=None):
        out: list = [None] * len(ranges)
        miss_idx: list[int] = []
        hit_bytes = 0
        for i, (off, ln) in enumerate(ranges):
            data = None
            try:
                data = self.cache.read(range_key(self.stream, off, ln))
            except FileNotFoundError:
                pass   # absent or evicted mid-flight: an ordinary miss
            if data is None or len(data) != ln:
                miss_idx.append(i)
                continue
            if self.job is not None:
                self.cache.pin(self.job, range_key(self.stream, off, ln))
            if into is None:
                out[i] = data
            else:
                memoryview(into[i])[:ln] = data
                out[i] = ln
            hit_bytes += ln
            self.cache_stats["hits"] += 1
        self.cache_stats["hit_bytes"] += hit_bytes
        if hit_bytes and self.on_hit is not None:
            self.on_hit(hit_bytes)
        if miss_idx:
            self.cache_stats["misses"] += len(miss_idx)
            self.cache_stats["miss_bytes"] += sum(
                ranges[i][1] for i in miss_idx)
            sub = self.inner.pread_many(
                [ranges[i] for i in miss_idx],
                into=None if into is None else [into[i] for i in miss_idx],
                priority=priority)
            for i, val in zip(miss_idx, sub):
                out[i] = val
        return out


def prefetch_ranges(reader, cache: "NodeCache", stream: str,
                    ranges, *, job: Optional[str] = None,
                    priority: Optional[int] = None,
                    batch_bytes: int = 128 * (1 << 20)) -> int:
    """Pull ``(offset, length)`` ranges of ``stream`` through ``reader``
    into ``cache`` as range-addressed entries (the restore-ahead
    producer).  Already-cached ranges are skipped, so re-arming after
    every checkpoint is cheap when little changed.  Reads are batched to
    bound transient memory; ``priority`` rides through to the reader (the
    runtime prefetches at DEFERRED so restore-ahead can never convoy a
    live startup).  Returns the number of bytes newly admitted."""
    todo = [(off, ln) for off, ln in ranges
            if ln > 0 and not cache.has(range_key(stream, off, ln))]
    stored = 0
    i = 0
    while i < len(todo):
        j, acc = i, 0
        while j < len(todo) and (j == i or acc + todo[j][1] <= batch_bytes):
            acc += todo[j][1]
            j += 1
        payloads = reader.pread_many(todo[i:j], priority=priority)
        for (off, ln), data in zip(todo[i:j], payloads):
            if len(data) != ln:
                raise IOError(
                    f"restore-ahead short read: {len(data)} of {ln} bytes "
                    f"at offset {off}")
            if cache.put(range_key(stream, off, ln), data, job=job):
                stored += ln
        i = j
    return stored


class NodeCache:
    """See module docstring.  ``capacity_bytes=None`` means unbounded
    (the pre-fabric behaviour every consumer starts from)."""

    def __init__(self, root: str | Path, *,
                 capacity_bytes: Optional[int] = None,
                 policy: EvictionPolicy | str = "lru",
                 score_fn: Optional[Callable[[str], float]] = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.capacity_bytes = capacity_bytes
        if isinstance(policy, str):
            if policy == "lru":
                policy = LRUPolicy()
            elif policy == "hot":
                policy = HotScorePolicy(score_fn or (lambda _k: 0.0))
            else:
                raise ValueError(
                    f"unknown eviction policy {policy!r}: expected 'lru', "
                    "'hot', or an EvictionPolicy instance")
        self.policy = policy
        self._lock = threading.Lock()
        self._sizes: Dict[str, int] = {}
        self._bytes = 0
        # job tag -> pinned keys; a key may be pinned by several jobs
        self._pins: Dict[str, Set[str]] = {}
        self._pin_counts: Dict[str, int] = {}
        # keys reserved in the index whose file is still being written:
        # never eviction victims until the write lands (a victim pick
        # would unlink nothing, then the late write would publish bytes
        # the index no longer tracks)
        self._inflight_writes: Set[str] = set()
        self._flights: Dict[str, threading.Lock] = {}
        self._listeners: Dict[str, Callable[[str], None]] = {}
        self.stats = {"hits": 0, "misses": 0, "evictions": 0,
                      "evicted_bytes": 0, "over_capacity_admits": 0,
                      "singleflight_hits": 0}
        # warm restart: rebuild the index from whatever survived on disk
        for p in self.root.iterdir():
            if p.is_file() and _is_cache_entry(p.name):
                self._index(p.name, p.stat().st_size)

    # ----- index internals (call under self._lock or during __init__) ---

    def _index(self, key: str, nbytes: int):
        if key not in self._sizes:
            self._bytes += nbytes
            self._sizes[key] = nbytes
            self.policy.on_admit(key)

    def _deindex(self, key: str) -> int:
        nbytes = self._sizes.pop(key, 0)
        self._bytes -= nbytes
        self.policy.on_remove(key)
        return nbytes

    # ----- public surface ----------------------------------------------

    def path(self, key: str) -> Path:
        return self.root / key

    def has(self, key: str) -> bool:
        with self._lock:
            return key in self._sizes

    def keys(self) -> list:
        with self._lock:
            return list(self._sizes)

    @property
    def bytes_used(self) -> int:
        with self._lock:
            return self._bytes

    def read(self, key: str) -> bytes:
        """Entry payload.  Raises ``FileNotFoundError`` when the key is
        absent or was evicted — callers treat that exactly like a miss
        (the swarm's serve path already maps OSError to "drop holder")."""
        with self._lock:
            known = key in self._sizes
            if known:
                self.policy.on_access(key)
        if not known:
            raise FileNotFoundError(f"node cache entry {key!r} not present")
        data = self.path(key).read_bytes()
        with self._lock:
            self.stats["hits"] += 1
        return data

    # ----- pinning ------------------------------------------------------

    def pin(self, job: str, key: str):
        """Pin ``key`` for ``job``: not an eviction victim until every
        pinning job releases (``unpin_job``)."""
        with self._lock:
            held = self._pins.setdefault(job, set())
            if key not in held:
                held.add(key)
                self._pin_counts[key] = self._pin_counts.get(key, 0) + 1

    def unpin_job(self, job: str):
        """Release every pin ``job`` holds (end of its startup/restore)."""
        with self._lock:
            for key in self._pins.pop(job, ()):
                n = self._pin_counts.get(key, 0) - 1
                if n <= 0:
                    self._pin_counts.pop(key, None)
                else:
                    self._pin_counts[key] = n

    def pinned_keys(self) -> set:
        with self._lock:
            return set(self._pin_counts)

    # ----- eviction listeners ------------------------------------------

    def set_evict_listener(self, tag: str, fn: Optional[Callable]):
        """Register (or, with ``None``, remove) a listener called with
        each evicted/invalidated key — e.g. a swarm-availability
        withdrawal.  Keyed by ``tag`` so a warm-restarted client simply
        replaces its predecessor's listener."""
        with self._lock:
            if fn is None:
                self._listeners.pop(tag, None)
            else:
                self._listeners[tag] = fn

    def _notify_evicted(self, keys):
        for fn in list(self._listeners.values()):
            for key in keys:
                try:
                    fn(key)
                except Exception:  # noqa: BLE001 — advisory only
                    pass

    # ----- admission / eviction ----------------------------------------

    def _make_room(self, incoming: int) -> list:
        """Evict (under the lock) until ``incoming`` fits; returns the
        evicted keys.  Pinned keys are skipped; if pins alone exceed
        capacity the admit proceeds over budget (a running restore beats
        a strict bound — counted so benchmarks can see it)."""
        evicted = []
        if self.capacity_bytes is None:
            return evicted
        if self._bytes + incoming > self.capacity_bytes:
            for key in self.policy.victims():
                if self._bytes + incoming <= self.capacity_bytes:
                    break
                if key in self._pin_counts or key in self._inflight_writes \
                        or key not in self._sizes:
                    continue
                nbytes = self._deindex(key)
                self.path(key).unlink(missing_ok=True)
                evicted.append(key)
                self.stats["evictions"] += 1
                self.stats["evicted_bytes"] += nbytes
        if self._bytes + incoming > self.capacity_bytes:
            self.stats["over_capacity_admits"] += 1
        return evicted

    def put(self, key: str, data: bytes, *, job: Optional[str] = None) -> bool:
        """Admit ``data`` under ``key`` (atomic publish).  Returns whether
        THIS call stored it — a lost race with a concurrent writer is not
        an admission.  ``job`` optionally pins the entry for that job.

        The index entry is RESERVED (room made + bytes counted) before the
        file write, atomically under the index lock — otherwise N
        concurrent admits could each see a cache with room and
        collectively blow the byte bound."""
        p = self.path(key)
        tmp = p.with_name(p.name + f".tmp{threading.get_ident():x}")
        reserved = False
        try:
            with self._lock:
                present = key in self._sizes
                if not present:
                    evicted = self._make_room(len(data))
                    self._index(key, len(data))
                    self._inflight_writes.add(key)
                    reserved = True
                else:
                    evicted = []
                    self.policy.on_access(key)
            # inside the try: a raising eviction subscriber must roll the
            # reservation back, not leak the index entry + write marker
            self._notify_evicted(evicted)
            if present:
                if job is not None:
                    self.pin(job, key)
                return False
            tmp.write_bytes(data)
            os.link(tmp, p)        # atomic publish; loser keeps p intact
            stored = True
        except FileExistsError:
            stored = False         # concurrent writer won; bytes identical
        except BaseException:
            if reserved:
                with self._lock:
                    self._deindex(key)
            raise
        finally:
            if reserved:
                tmp.unlink(missing_ok=True)
                with self._lock:
                    self._inflight_writes.discard(key)
        if job is not None:
            self.pin(job, key)
        return stored

    def admit_file(self, key: str, tmp_path: Path, *,
                   job: Optional[str] = None) -> Path:
        """Admit an already-written temp file (streamed producers: env
        archives) by renaming it into the cache.  Returns the entry path."""
        nbytes = Path(tmp_path).stat().st_size
        dest = self.path(key)
        reserved = False
        try:
            with self._lock:
                fresh = key not in self._sizes
                evicted = self._make_room(nbytes if fresh else 0)
                if fresh:
                    self._index(key, nbytes)
                    self._inflight_writes.add(key)
                    reserved = True
            self._notify_evicted(evicted)
            Path(tmp_path).replace(dest)
        except BaseException:
            if reserved:
                with self._lock:
                    self._deindex(key)
            raise
        finally:
            if reserved:
                with self._lock:
                    self._inflight_writes.discard(key)
        if job is not None:
            self.pin(job, key)
        return dest

    # ----- singleflight -------------------------------------------------

    def _flight_lock(self, key: str) -> threading.Lock:
        with self._lock:
            return self._flights.setdefault(key, threading.Lock())

    def _retire_flight(self, key: str) -> None:
        """Drop the flight entry once ``key`` is admitted: future callers
        take the ``has()`` fast path before ever reaching the flight
        lock, and stragglers already blocked on the old lock object
        re-check ``has()`` after acquiring it.  Keeps ``_flights``
        bounded by in-progress fetches instead of every key ever seen."""
        with self._lock:
            self._flights.pop(key, None)

    def fetch_path(self, key: str, producer: Callable[[Path], None], *,
                   job: Optional[str] = None) -> Tuple[Path, bool]:
        """Singleflight admission: returns ``(entry path, was_hit)``.

        On a miss, exactly one caller per node runs ``producer(tmp_path)``
        (which must write the payload to ``tmp_path``); everyone else
        blocks on the flight and then reads the admitted entry.
        """
        if self.has(key):
            with self._lock:
                self.policy.on_access(key)
                self.stats["hits"] += 1
            if job is not None:
                self.pin(job, key)
            return self.path(key), True
        with self._flight_lock(key):
            if self.has(key):
                with self._lock:
                    self.stats["hits"] += 1
                    self.stats["singleflight_hits"] += 1
                if job is not None:
                    self.pin(job, key)
                self._retire_flight(key)
                return self.path(key), True
            with self._lock:
                self.stats["misses"] += 1
            tmp = self.path(key).with_name(
                self.path(key).name + f".tmp{os.getpid():x}")
            try:
                producer(tmp)
                dest = self.admit_file(key, tmp, job=job)
            finally:
                tmp.unlink(missing_ok=True)
        self._retire_flight(key)
        return dest, False

    def get_or_fetch(self, key: str, fetch: Callable[[], bytes], *,
                     job: Optional[str] = None) -> bytes:
        """Singleflight byte fetch (block-sized payloads)."""
        try:
            data = self.read(key)
            if job is not None:
                self.pin(job, key)
            return data
        except FileNotFoundError:
            pass
        with self._flight_lock(key):
            try:
                data = self.read(key)
                with self._lock:
                    self.stats["singleflight_hits"] += 1
                if job is not None:
                    self.pin(job, key)
                self._retire_flight(key)
                return data
            except FileNotFoundError:
                with self._lock:
                    self.stats["misses"] += 1
            data = fetch()
            self.put(key, data, job=job)
        self._retire_flight(key)
        return data

    # ----- invalidation -------------------------------------------------

    def invalidate(self, key: str) -> bool:
        """Drop ``key`` (expiry, corruption): file + index + listeners."""
        with self._lock:
            known = self._deindex(key) > 0 or self.path(key).exists()
        self.path(key).unlink(missing_ok=True)
        if known:
            self._notify_evicted([key])
        return known

    def invalidate_prefix(self, prefix: str) -> int:
        """Drop every entry whose key starts with ``prefix`` (an expired
        env key invalidates all its content-addressed archive versions)."""
        with self._lock:
            doomed = [k for k in self._sizes if k.startswith(prefix)]
        return sum(1 for k in doomed if self.invalidate(k))
