"""Placement — how durable (DFS) data maps onto physical stripe files.

Three strategies, all sharing the striped chunk/unit layout of
``repro.dfs.striped`` for the *data* files (the on-disk data layout is
byte-identical across strategies, so switching placement never rewrites
readers):

* ``striped``      — today's layout, nothing extra.  A lost physical
  stripe file is a loud ``StripeMissingError`` (operator repairs the
  replica), exactly the pre-fabric behaviour.
* ``replicated``   — each data stripe file is mirrored ``replicas``
  times into other DataNode groups; a lost/truncated primary falls over
  to a replica (storage cost x(1+replicas), zero read overhead).
* ``erasure``      — Reed-Solomon over GF(256): ``k = width`` data files
  plus ``m`` parity files (Cauchy-systematic, see repro.fabric.gf256).
  Parity is computed *byte-wise at identical file offsets*, so
  reconstructing any byte range of a lost file reads only the SAME
  range from k survivors — no stripe-row alignment, no full-file reads.
  Storage cost x(1+m/k); degraded reads cost k x the missing range.

Erasure placement also records a CRC per 1 MB chunk of every data and
parity file, so a *corrupted* stripe payload (bad bytes, right length)
is detected at read time and reconstructed like a missing chunk instead
of being returned as tensor bytes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

STRIPED = "striped"
REPLICATED = "replicated"
ERASURE = "erasure"


@dataclass(frozen=True)
class Placement:
    """Declarative placement config (writer input) and, once written,
    the layout record the reader decodes (``to_attrs``/``from_attrs``)."""

    kind: str = STRIPED
    replicas: int = 1          # replicated: mirror copies per data file
    parity: int = 2            # erasure: m parity files
    verify: bool = True        # erasure: CRC-check chunks on read
    # replicated: place each mirror a whole REGION stride away from its
    # data file (HdfsCluster.num_regions partitions the DataNode groups
    # into region tiers), so losing an entire region's groups still
    # leaves a full copy elsewhere and a remote region's restore reads
    # its own region-local mirror instead of crossing the WAN.  With
    # num_regions == 1 this is a no-op (the classic adjacent-group
    # mirror layout).
    region_spread: bool = False

    # filled in by the writer at close():
    replica_files: tuple = ()  # per data file: ((group, name), ...)
    parity_files: tuple = ()   # ((group, name), ...)
    file_lengths: tuple = ()   # physical data-file lengths (bytes)
    parity_length: int = 0
    chunk_crc: dict = field(default_factory=dict)
    # {"data": [[crc per chunk] per file], "parity": [[...] per file]}

    def __post_init__(self):
        if self.kind not in (STRIPED, REPLICATED, ERASURE):
            raise ValueError(
                f"unknown placement kind {self.kind!r}: expected "
                f"'{STRIPED}', '{REPLICATED}' or '{ERASURE}'")
        if self.kind == REPLICATED and self.replicas < 1:
            raise ValueError("replicated placement needs replicas >= 1")
        if self.kind == ERASURE and self.parity < 1:
            raise ValueError("erasure placement needs parity >= 1")

    # ----- constructors -------------------------------------------------

    @classmethod
    def striped(cls) -> "Placement":
        return cls(kind=STRIPED)

    @classmethod
    def replicated(cls, replicas: int = 1, *,
                   region_spread: bool = False) -> "Placement":
        return cls(kind=REPLICATED, replicas=replicas,
                   region_spread=region_spread)

    @classmethod
    def erasure(cls, parity: int = 2, *, verify: bool = True) -> "Placement":
        return cls(kind=ERASURE, parity=parity, verify=verify)

    @classmethod
    def parse(cls, spec) -> "Placement":
        """Accept a Placement, a kind string, or None (-> striped)."""
        if spec is None:
            return cls.striped()
        if isinstance(spec, Placement):
            return spec
        if isinstance(spec, str):
            return cls(kind=spec)
        raise TypeError(f"cannot interpret placement spec {spec!r}")

    # ----- attrs serialization (namenode metadata) ----------------------

    def to_attrs(self) -> Optional[dict]:
        """Attrs payload, or ``None`` for plain striping — the striped
        layout's metadata stays byte-identical to the pre-fabric format."""
        if self.kind == STRIPED:
            return None
        out = {"kind": self.kind}
        if self.kind == REPLICATED:
            out["replicas"] = self.replicas
            out["region_spread"] = self.region_spread
            out["replica_files"] = [list(map(list, fs))
                                    for fs in self.replica_files]
        else:
            out["parity"] = self.parity
            out["verify"] = self.verify
            out["parity_files"] = [list(f) for f in self.parity_files]
            out["file_lengths"] = list(self.file_lengths)
            out["parity_length"] = self.parity_length
            out["chunk_crc"] = self.chunk_crc
        return out

    @classmethod
    def from_attrs(cls, raw: Optional[dict]) -> "Placement":
        if not raw:
            return cls.striped()
        if raw["kind"] not in (REPLICATED, ERASURE):
            # corrupt metadata or a newer writer: fail at open time with
            # the real reason, not mid-read with a bogus "unrecoverable"
            raise ValueError(
                f"unknown placement kind {raw['kind']!r} in file attrs")
        if raw["kind"] == REPLICATED:
            return cls(
                kind=REPLICATED, replicas=raw.get("replicas", 1),
                region_spread=raw.get("region_spread", False),
                replica_files=tuple(
                    tuple(tuple(f) for f in fs)
                    for fs in raw.get("replica_files", [])))
        return cls(
            kind=ERASURE, parity=raw.get("parity", 2),
            verify=raw.get("verify", True),
            parity_files=tuple(tuple(f) for f in raw.get("parity_files", [])),
            file_lengths=tuple(raw.get("file_lengths", [])),
            parity_length=raw.get("parity_length", 0),
            chunk_crc=raw.get("chunk_crc", {}))
