"""Region federation: a background replicator that turns WAN fetches
into LAN fetches.

A multi-region swarm (``repro.blockstore.swarm`` with a region tier in
its :class:`Topology`) already prefers same-rack > same-region >
cross-region holders — but a region only BECOMES self-sufficient after
someone in it has pulled each block across the WAN once.  The
:class:`RegionReplicator` makes that first pull proactive instead of
demand-driven: between startups it walks the merged hot-block heat map
(``HotBlockService.score_index()``, hottest first) and pulls any block a
region holds fewer than ``min_region_replicas`` copies of into one of
that region's registered clients, so the NEXT restart storm in that
region finds every hot block region-local.

Discipline rules (the same ones the rest of the startup stack obeys):

* **DEFERRED priority** — every replication pull runs at
  ``repro.core.pipeline.DEFERRED``; with a scheduler attached to the
  client, registry fallbacks hold one metered "registry" token per block
  and peer bytes land in the "peer" accounting pool, so replication can
  never queue a CRITICAL startup fetch behind it.  DEFERRED pulls also
  never pin: a bounded :class:`~repro.fabric.cache.NodeCache` may rotate
  replicated blocks out under pressure.
* **Eviction-withdraw honesty** — replicated blocks land in the pulling
  client's ``NodeCache`` through the ordinary ``ensure_block`` path, so
  the client's eviction listener withdraws them from the availability
  index the moment they leave disk; ``region_holder_count`` then drops
  and the next round simply replicates again.  Cross-region holders are
  never trusted beyond what the index can prove.
* **Bounded rounds** — each round moves at most ``max_bytes_per_round``
  bytes and ``max_blocks_per_round`` blocks per region; convergence is
  incremental, never a WAN burst.
* **No blocking under the lock** — membership is snapshotted under
  ``_lock`` and released before any pull; the I/O never runs inside it.
"""

from __future__ import annotations

import threading
from typing import Optional


class RegionReplicator:
    """Pull hot blocks into under-replicated regions, hottest first.

    Parameters
    ----------
    swarm: the region-aware :class:`~repro.blockstore.swarm.Swarm`.
    hot_service: the :class:`~repro.blockstore.prefetch.HotBlockService`
        whose merged ``score_index()`` ranks what is worth replicating.
    min_region_replicas: target region-local copies per hot block.
    max_bytes_per_round / max_blocks_per_round: per-region WAN budget of
        one :meth:`replicate_once` round.
    interval_s: background-thread cadence (:meth:`start`).
    """

    def __init__(self, swarm, hot_service, *,
                 min_region_replicas: int = 1,
                 max_bytes_per_round: int = 64 << 20,
                 max_blocks_per_round: int = 256,
                 interval_s: float = 5.0):
        if min_region_replicas < 1:
            raise ValueError(
                f"min_region_replicas must be >= 1, "
                f"got {min_region_replicas}")
        self.swarm = swarm
        self.hot_service = hot_service
        self.min_region_replicas = min_region_replicas
        self.max_bytes_per_round = max_bytes_per_round
        self.max_blocks_per_round = max_blocks_per_round
        self.interval_s = interval_s
        self._lock = threading.Lock()
        self._pullers: dict[str, list] = {}      # region -> clients
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.stats = {"rounds": 0, "replicated_blocks": 0,
                      "replicated_bytes": 0, "skipped_blocks": 0,
                      "errors": 0}

    # ----- membership -------------------------------------------------

    def register(self, client, region: Optional[str] = None):
        """Add ``client`` as a replication target for its region (derived
        from the swarm topology unless given).  The client must be
        swarm-attached — its pulls must publish/withdraw like any other
        member's."""
        region = region or self.swarm.topology.region_of(client.node_id)
        with self._lock:
            self._pullers.setdefault(region, []).append(client)
        return region

    def unregister(self, client):
        with self._lock:
            for clients in self._pullers.values():
                if client in clients:
                    clients.remove(client)

    def regions(self) -> list[str]:
        with self._lock:
            return sorted(r for r, cs in self._pullers.items() if cs)

    # ----- policy -----------------------------------------------------

    def under_replicated(self, region: str,
                         scores: Optional[dict] = None) -> list[str]:
        """Hot blocks with fewer than ``min_region_replicas`` live
        holders inside ``region``, hottest first.  Blocks NO swarm member
        holds are excluded — replication moves existing replicas closer,
        it never originates registry traffic for blocks the fleet has
        already dropped everywhere."""
        if scores is None:
            scores = self.hot_service.score_index()
        out = []
        for h in sorted(scores, key=scores.get, reverse=True):
            held = self.swarm.holder_count(h)
            if held == 0:
                continue
            if self.swarm.region_holder_count(
                    h, region) >= self.min_region_replicas:
                continue
            out.append(h)
        return out

    # ----- one replication round --------------------------------------

    def replicate_once(self) -> int:
        """Run one bounded round over every registered region; returns
        the number of blocks replicated.  Pull targets rotate round-robin
        over the region's clients so the replica set spreads instead of
        concentrating on one node."""
        from repro.core.pipeline import DEFERRED

        with self._lock:
            pullers = {r: list(cs) for r, cs in self._pullers.items()
                       if cs}
        scores = self.hot_service.score_index()
        moved_blocks = moved_bytes = skipped = errors = 0
        for region, clients in pullers.items():
            budget = self.max_bytes_per_round
            pulled = 0
            for i, h in enumerate(self.under_replicated(region, scores)):
                if budget <= 0 or pulled >= self.max_blocks_per_round:
                    break
                client = clients[i % len(clients)]
                if client.has_block(h):
                    # on disk but index-short (e.g. a concurrent
                    # withdraw landed between count and check): let the
                    # next round re-evaluate rather than double-pull
                    skipped += 1
                    continue
                try:
                    data = client.ensure_block(h, priority=DEFERRED)
                except OSError:
                    # holder vanished AND the registry refused: count it
                    # and move on — a round must survive any one block
                    errors += 1
                    continue
                pulled += 1
                moved_blocks += 1
                moved_bytes += len(data)
                budget -= len(data)
        with self._lock:
            self.stats["rounds"] += 1
            self.stats["replicated_blocks"] += moved_blocks
            self.stats["replicated_bytes"] += moved_bytes
            self.stats["skipped_blocks"] += skipped
            self.stats["errors"] += errors
        return moved_blocks

    # ----- background thread ------------------------------------------

    def start(self):
        """Start the background replication loop (idempotent)."""
        with self._lock:
            if self._thread is not None:
                return
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="region-replicator", daemon=True)
            thread = self._thread
        thread.start()

    def stop(self, timeout: float = 10.0):
        """Signal the loop to exit and join it (idempotent)."""
        with self._lock:
            thread, self._thread = self._thread, None
        self._stop.set()
        if thread is not None:
            thread.join(timeout)

    def _loop(self):
        while not self._stop.wait(self.interval_s):
            try:
                self.replicate_once()
            except Exception:
                # the loop must outlive any one bad round (a vanished
                # client, a torn record file); failures are visible in
                # stats, never fatal to the daemon
                with self._lock:
                    self.stats["errors"] += 1
