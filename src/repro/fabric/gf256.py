"""GF(256) arithmetic and Cauchy Reed-Solomon coding (pure numpy).

The storage fabric's erasure placement codes ``k`` data stripe files with
``m`` parity stripe files so any ``m`` lost files reconstruct from the
survivors (an MDS code).  Everything here is byte-wise over the field
GF(2^8) with the AES-ish polynomial ``x^8+x^4+x^3+x^2+1`` (0x11d, the
classic Rijndael-adjacent choice used by most RS storage systems):

* multiplication by a *fixed* coefficient is a single 256-entry table
  lookup, so numpy fancy indexing vectorizes an entire stripe-chunk
  multiply into one ``take`` — no per-byte Python;
* the generator is **systematic Cauchy**: data shards are stored verbatim
  and the parity rows come from a Cauchy matrix ``C[i][j] =
  1/(x_i + y_j)``.  Every square submatrix of a Cauchy matrix is
  invertible, which (unlike a naive Vandermonde stack) guarantees ANY k
  of the k+m shards decode — the property the fault-tolerance story
  rests on.

Only encode/decode of equal-length byte blocks lives here; how blocks map
onto stripe files is the placement layer's job (repro.fabric.placement /
repro.dfs.striped).
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

_POLY = 0x11D

# EXP doubled so EXP[LOG[a] + LOG[b]] never needs an explicit mod 255
EXP = np.zeros(510, dtype=np.uint8)
LOG = np.zeros(256, dtype=np.int64)
_x = 1
for _i in range(255):
    EXP[_i] = _x
    LOG[_x] = _i
    _x <<= 1
    if _x & 0x100:
        _x ^= _POLY
EXP[255:510] = EXP[:255]


def gf_mul(a: int, b: int) -> int:
    if a == 0 or b == 0:
        return 0
    return int(EXP[LOG[a] + LOG[b]])


def gf_inv(a: int) -> int:
    if a == 0:
        raise ZeroDivisionError("gf_inv(0)")
    return int(EXP[255 - LOG[a]])


# per-coefficient multiplication tables: MUL_TABLE(c)[b] == c * b.
# Built lazily and cached — a (k+m)-wide code touches at most k*m distinct
# coefficients plus whatever a decode matrix produces.
_MUL_TABLES: Dict[int, np.ndarray] = {}


def mul_table(c: int) -> np.ndarray:
    t = _MUL_TABLES.get(c)
    if t is None:
        if c == 0:
            t = np.zeros(256, dtype=np.uint8)
        else:
            t = np.empty(256, dtype=np.uint8)
            t[0] = 0
            b = np.arange(1, 256)
            t[1:] = EXP[LOG[c] + LOG[b]]
        _MUL_TABLES[c] = t
    return t


def gf_mul_bytes(c: int, data: np.ndarray) -> np.ndarray:
    """c * data, element-wise over GF(256) — one vectorized table lookup."""
    if c == 0:
        return np.zeros_like(data)
    if c == 1:
        return data.copy()
    return mul_table(c)[data]


def cauchy_matrix(m: int, k: int) -> List[List[int]]:
    """m x k Cauchy matrix C[i][j] = 1/(x_i + y_j) with x_i = k+i, y_j = j.

    All x and y values are distinct elements of GF(256) (requires
    k + m <= 256), so every square submatrix is invertible.
    """
    if k + m > 256:
        raise ValueError(f"k+m must be <= 256 for GF(256), got {k}+{m}")
    return [[gf_inv((k + i) ^ j) for j in range(k)] for i in range(m)]


def gf_matinv(a: Sequence[Sequence[int]]) -> List[List[int]]:
    """Invert a small square matrix over GF(256) (Gauss-Jordan).

    k is the stripe width (<= a few dozen), so plain Python loops over
    rows are fine; the expensive part of decode is the byte-vector math,
    which goes through the vectorized tables.
    """
    n = len(a)
    aug = [list(row) + [1 if i == j else 0 for j in range(n)]
           for i, row in enumerate(a)]
    for col in range(n):
        piv = next((r for r in range(col, n) if aug[r][col] != 0), None)
        if piv is None:
            raise ValueError("singular matrix over GF(256)")
        aug[col], aug[piv] = aug[piv], aug[col]
        inv_p = gf_inv(aug[col][col])
        aug[col] = [gf_mul(v, inv_p) for v in aug[col]]
        for r in range(n):
            if r != col and aug[r][col]:
                f = aug[r][col]
                aug[r] = [v ^ gf_mul(f, w)
                          for v, w in zip(aug[r], aug[col])]
    return [row[n:] for row in aug]


def _combine(coeffs: Sequence[int],
             blocks: Sequence[np.ndarray]) -> np.ndarray:
    """XOR-sum of coeff_i * block_i (one output shard's worth of math)."""
    out: Optional[np.ndarray] = None
    for c, b in zip(coeffs, blocks):
        if c == 0:
            continue
        term = gf_mul_bytes(c, b)
        if out is None:
            out = term
        else:
            np.bitwise_xor(out, term, out=out)
    if out is None:
        out = np.zeros_like(blocks[0])
    return out


def rs_encode(data: Sequence[np.ndarray], m: int) -> List[np.ndarray]:
    """``m`` parity blocks over ``k = len(data)`` equal-length data blocks."""
    k = len(data)
    c = cauchy_matrix(m, k)
    return [_combine(c[i], data) for i in range(m)]


def rs_decode(shards: Dict[int, np.ndarray], k: int, m: int,
              want: Iterable[int]) -> Dict[int, np.ndarray]:
    """Reconstruct shards from any ``k`` survivors.

    ``shards``: shard index -> byte block; indices 0..k-1 are data, k..k+m-1
    parity.  ``want``: indices to reconstruct (data or parity).  Raises
    ``ValueError`` when fewer than k shards are present (more than m
    failures: the code's recovery bound).
    """
    want = list(want)
    if len(shards) < k:
        raise ValueError(
            f"need at least k={k} shards to decode, have {len(shards)} "
            f"(lost {k + m - len(shards)} > m={m})")
    cau = cauchy_matrix(m, k)

    def gen_row(idx: int) -> List[int]:
        if idx < k:
            return [1 if j == idx else 0 for j in range(k)]
        return cau[idx - k]

    use = sorted(shards)[:k]
    a = [gen_row(i) for i in use]
    inv = gf_matinv(a)          # data_j = sum_l inv[j][l] * shard_use[l]
    used_blocks = [shards[i] for i in use]
    out: Dict[int, np.ndarray] = {}
    data_cache: Dict[int, np.ndarray] = {}

    def data_shard(j: int) -> np.ndarray:
        if j in data_cache:
            return data_cache[j]
        blk = shards[j] if j in shards else _combine(inv[j], used_blocks)
        data_cache[j] = blk
        return blk

    for idx in want:
        if idx in shards:
            out[idx] = shards[idx]
        elif idx < k:
            out[idx] = data_shard(idx)
        else:  # lost parity: re-encode from (possibly reconstructed) data
            out[idx] = _combine(cau[idx - k],
                                [data_shard(j) for j in range(k)])
    return out
