"""The storage fabric: shared node caching, placement and erasure coding
for every BootSeer storage consumer (blockstore, envcache, striped DFS).

See repro.fabric.cache (NodeCache + eviction policies),
repro.fabric.placement (striped / replicated / erasure strategies),
repro.fabric.federation (cross-region hot-block replication) and
repro.fabric.gf256 (the Reed-Solomon kernel).
"""

from repro.fabric.cache import (EvictionPolicy, HotScorePolicy, LRUPolicy,
                                NodeCache)
from repro.fabric.federation import RegionReplicator
from repro.fabric.gf256 import rs_decode, rs_encode
from repro.fabric.placement import ERASURE, REPLICATED, STRIPED, Placement

__all__ = [
    "EvictionPolicy", "HotScorePolicy", "LRUPolicy", "NodeCache",
    "Placement", "STRIPED", "REPLICATED", "ERASURE",
    "RegionReplicator",
    "rs_encode", "rs_decode",
]
