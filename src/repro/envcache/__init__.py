from repro.envcache.snapshot import (  # noqa: F401
    EnvCache, snapshot_dir, diff_snapshots, job_cache_key)
