"""Job-level environment cache — dependency snapshotting (BootSeer §4.3,
Fig. 10).

First run of a job: snapshot the *target directory* (e.g. site-packages)
before and after the Environment Setup phase on node 0; every file added or
modified is packed into a compressed archive and uploaded to the DFS keyed
by the job's parameters.  Subsequent runs / restarts / node replacements of
the SAME job restore the archive and skip every install command.  If the job
parameters change (dependency versions, GPU type, OS, region...), the key
changes, so the stale cache simply never matches — expiry is structural.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import tarfile
import time
from pathlib import Path
from typing import Optional

try:
    import zstandard as zstd

    def _compress(data: bytes) -> bytes:
        return zstd.ZstdCompressor(level=3).compress(data)

    def _decompress(data: bytes) -> bytes:
        return zstd.ZstdDecompressor().decompress(data)

    COMPRESSION = "zstd"
except ImportError:  # pragma: no cover
    import gzip

    def _compress(data: bytes) -> bytes:
        return gzip.compress(data, 6)

    def _decompress(data: bytes) -> bytes:
        return gzip.decompress(data)

    COMPRESSION = "gzip"


def snapshot_dir(target: str | Path) -> dict[str, tuple[int, int]]:
    """{relpath: (size, mtime_ns)} for every file under target."""
    target = Path(target)
    snap = {}
    if not target.exists():
        return snap
    for p in target.rglob("*"):
        if p.is_file():
            st = p.stat()
            snap[str(p.relative_to(target))] = (st.st_size, st.st_mtime_ns)
    return snap


def diff_snapshots(before: dict, after: dict) -> list[str]:
    """Paths added or modified between two snapshots."""
    return sorted(p for p, sig in after.items()
                  if p not in before or before[p] != sig)


def job_cache_key(job_params: dict) -> str:
    """Deterministic cache key over the job's runtime parameters."""
    blob = json.dumps(job_params, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:24]


class EnvCache:
    """Create/restore environment caches in the DFS (via HDFS-FUSE mount)."""

    def __init__(self, mount, base: str = "/envcache"):
        self.mount = mount  # HdfsFuseMount
        self.base = base.rstrip("/")

    def _data_path(self, key: str) -> str:
        return f"{self.base}/{key}.tar.{COMPRESSION}"

    def _meta_path(self, key: str) -> str:
        return f"{self.base}/{key}.meta.json"

    def exists(self, key: str) -> bool:
        return self.mount.exists(self._data_path(key)) and \
            self.mount.exists(self._meta_path(key))

    def expire(self, key: str):
        for p in (self._data_path(key), self._meta_path(key)):
            if self.mount.exists(p):
                self.mount.hdfs.delete(self.mount._full(p))

    # ----- create (first run, node 0) -----

    def create(self, key: str, target: str | Path, before: dict,
               job_params: Optional[dict] = None, *, striped: bool = True) -> dict:
        """Capture the diff of ``target`` vs ``before`` and upload."""
        target = Path(target)
        after = snapshot_dir(target)
        changed = diff_snapshots(before, after)
        buf = io.BytesIO()
        with tarfile.open(fileobj=buf, mode="w") as tar:
            for rel in changed:
                tar.add(target / rel, arcname=rel)
        raw = buf.getvalue()
        packed = _compress(raw)
        self.mount.write(self._data_path(key), packed, striped=striped)
        meta = {"key": key, "files": len(changed),
                "raw_bytes": len(raw), "packed_bytes": len(packed),
                "compression": COMPRESSION, "created": time.time(),
                "job_params": job_params or {}}
        self.mount.write(self._meta_path(key),
                         json.dumps(meta).encode())
        return meta

    # ----- restore (subsequent runs, every node) -----

    def restore(self, key: str, target: str | Path) -> Optional[dict]:
        """Extract the cached environment into ``target``.  Returns the cache
        meta, or None when no valid cache exists (caller falls back to the
        real install commands)."""
        if not self.exists(key):
            return None
        meta = json.loads(self.mount.open(self._meta_path(key)).read())
        packed = self.mount.open(self._data_path(key)).read()
        raw = _decompress(packed)
        target = Path(target)
        target.mkdir(parents=True, exist_ok=True)
        with tarfile.open(fileobj=io.BytesIO(raw)) as tar:
            tar.extractall(target, filter="data")
        return meta
