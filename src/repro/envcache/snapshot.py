"""Job-level environment cache — dependency snapshotting (BootSeer §4.3,
Fig. 10).

First run of a job: snapshot the *target directory* (e.g. site-packages)
before and after the Environment Setup phase on node 0; every file added or
modified is packed into a compressed archive and uploaded to the DFS keyed
by the job's parameters.  Subsequent runs / restarts / node replacements of
the SAME job restore the archive and skip every install command.  If the job
parameters change (dependency versions, GPU type, OS, region...), the key
changes, so the stale cache simply never matches — expiry is structural.

Restore hot path
----------------
Restore is on the warm-restart critical path, so it is built to beat a
fresh install rather than merely match it:

* the packed archive is fetched from the DFS with the striped reader's
  ``width``-way parallel ``pread`` (large windows, not one whole-buffer
  ``read()``);
* with a ``local_cache`` directory configured, the blob is fetched from the
  DFS **once per node** and memoized in a storage-fabric
  :class:`~repro.fabric.cache.NodeCache` — N concurrent restores (one per
  worker thread) share a single DFS fetch instead of hammering the shared
  throttle N times (the cache's singleflight admission), and
  ``local_cache_bytes`` bounds the node's archive footprint (LRU).
  Entries are **content-addressed** (job key + archive digest), so a
  re-snapshot under the same job key can never be served a stale node-local
  archive — the new digest simply never matches the old entry;
* decompression is streamed into the tar reader (no second whole-archive
  buffer);
* extraction replicates the stdlib ``data`` filter's safety checks manually
  (works on Pythons whose ``extractall`` lacks ``filter=``, < 3.12) and
  writes file payloads through a small thread pool.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import stat as stat_mod
import tarfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import BinaryIO, Optional

from repro.fabric.cache import NodeCache

try:
    import zstandard as zstd

    def _compress(data: bytes) -> bytes:
        return zstd.ZstdCompressor(level=3).compress(data)

    def _decompress(data: bytes) -> bytes:
        return zstd.ZstdDecompressor().decompress(data)

    def _decompress_stream(fileobj: BinaryIO) -> BinaryIO:
        return zstd.ZstdDecompressor().stream_reader(fileobj)

    COMPRESSION = "zstd"
except ImportError:  # pragma: no cover
    import gzip

    def _compress(data: bytes) -> bytes:
        return gzip.compress(data, 6)

    def _decompress(data: bytes) -> bytes:
        return gzip.decompress(data)

    def _decompress_stream(fileobj: BinaryIO) -> BinaryIO:
        return gzip.GzipFile(fileobj=fileobj, mode="rb")

    COMPRESSION = "gzip"

# default DFS fetch window: one full stripe row of the default striped
# layout (8 files x 4 MB) so a windowed fetch keeps all spindles busy
FETCH_WINDOW = 32 * 1024 * 1024


def snapshot_dir(target: str | Path) -> dict[str, tuple[int, int]]:
    """{relpath: (size, mtime_ns)} for every file under target."""
    target = Path(target)
    snap = {}
    if not target.exists():
        return snap
    for p in target.rglob("*"):
        if p.is_file():
            st = p.stat()
            snap[str(p.relative_to(target))] = (st.st_size, st.st_mtime_ns)
    return snap


def diff_snapshots(before: dict, after: dict) -> list[str]:
    """Paths added or modified between two snapshots."""
    return sorted(p for p, sig in after.items()
                  if p not in before or before[p] != sig)


def job_cache_key(job_params: dict) -> str:
    """Deterministic cache key over the job's runtime parameters."""
    blob = json.dumps(job_params, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:24]


class _WindowedReader(io.RawIOBase):
    """File-like view over a DFS handle that reads ahead in large windows.

    Decompressors issue many small ``read()`` calls; each ``pread`` on a
    striped file costs a parallel fan-out, so serving small reads from a
    ``window``-sized buffer turns thousands of tiny reads into a handful of
    width-way-parallel ones.
    """

    def __init__(self, handle, size: int, window: int = FETCH_WINDOW,
                 sched=None, priority: int = 0):
        self._h = handle
        self._size = size
        self._window = max(window, 1)
        self._pos = 0
        self._buf = b""
        self._buf_start = 0
        # optional IOScheduler: each window fetch holds one "dfs" token,
        # so archive restores share the DFS with checkpoint preads under
        # priority order instead of free-for-all
        self._sched = sched
        self._priority = priority

    def readable(self) -> bool:
        return True

    def _fetch_window(self, pos: int, ln: int) -> bytes:
        if self._sched is not None:
            with self._sched.slot("dfs", priority=self._priority,
                                  nbytes=ln):
                return self._h.pread(pos, ln)
        return self._h.pread(pos, ln)

    def read(self, n: int = -1) -> bytes:
        if n is None or n < 0:
            n = self._size - self._pos
        out = bytearray()
        while n > 0 and self._pos < self._size:
            off = self._pos - self._buf_start
            if not (0 <= off < len(self._buf)):
                self._buf_start = self._pos
                self._buf = self._fetch_window(
                    self._pos, min(self._window, self._size - self._pos))
                if not self._buf:
                    break
                off = 0
            take = self._buf[off:off + n]
            out += take
            self._pos += len(take)
            n -= len(take)
        return bytes(out)


def _unsafe_path(name: str) -> bool:
    return (name.startswith("/") or os.path.isabs(name)
            or ".." in name.replace("\\", "/").split("/"))


def _check_member(member: tarfile.TarInfo) -> None:
    """Reject archive members that would escape the extraction root
    (absolute paths, ``..`` traversal, devices) — the safety core of the
    stdlib ``data`` filter, replicated so restore works on Pythons whose
    ``extractall`` has no ``filter=`` parameter (< 3.12)."""
    if _unsafe_path(member.name):
        raise tarfile.TarError(f"unsafe path in env archive: {member.name!r}")
    if member.isdev():
        raise tarfile.TarError(f"device node in env archive: {member.name!r}")
    if (member.islnk() or member.issym()) and _unsafe_path(member.linkname):
        raise tarfile.TarError(
            f"unsafe link target in env archive: {member.linkname!r}")


def _write_member(target: Path, member: tarfile.TarInfo, data: bytes):
    dest = target / member.name
    dest.parent.mkdir(parents=True, exist_ok=True)
    dest.write_bytes(data)
    # clamp mode like the data filter: keep owner rwx, drop setuid etc.
    mode = member.mode
    if mode is not None:
        os.chmod(dest, (mode | 0o600) & 0o777 & ~stat_mod.S_ISUID
                 & ~stat_mod.S_ISGID)


class EnvCache:
    """Create/restore environment caches in the DFS (via HDFS-FUSE mount).

    ``local_cache``: optional node-local directory memoizing fetched
    archives in a storage-fabric :class:`NodeCache`, so any number of
    concurrent restores on this node cost one DFS fetch per key;
    ``local_cache_bytes`` bounds it (LRU eviction; ``None`` = unbounded).
    A pre-built :class:`NodeCache` may be passed directly as
    ``local_cache`` to share one fabric cache across consumers.
    ``extract_threads`` sizes the restore-side file writer pool.
    ``placement`` selects the DFS durability strategy for the packed
    archive (striped / replicated / erasure — see repro.fabric.placement).
    """

    def __init__(self, mount, base: str = "/envcache", *,
                 local_cache: Optional[str | Path | NodeCache] = None,
                 local_cache_bytes: Optional[int] = None,
                 extract_threads: int = 4,
                 fetch_window: int = FETCH_WINDOW, sched=None,
                 placement=None):
        self.mount = mount  # HdfsFuseMount
        self.base = base.rstrip("/")
        self.extract_threads = max(1, extract_threads)
        self.fetch_window = fetch_window
        self.placement = placement
        # optional repro.core.pipeline.IOScheduler shared with the other
        # startup engines (window fetches hold "dfs" tokens)
        self.sched = sched
        if isinstance(local_cache, NodeCache):
            self._local: Optional[NodeCache] = local_cache
        elif local_cache is not None:
            self._local = NodeCache(local_cache,
                                    capacity_bytes=local_cache_bytes)
        else:
            self._local = None
        self._flight_master = threading.Lock()
        self._in_flight: dict[str, threading.Lock] = {}
        self._pool: Optional[ThreadPoolExecutor] = None
        # meta blobs are treated as immutable per (key, generation):
        # create() and expire() both invalidate, so concurrent restores
        # share one DFS meta read without ever serving a stale entry
        self._meta_cache: dict[str, dict] = {}
        self.stats = {"dfs_archive_fetches": 0, "local_cache_hits": 0}

    # writes below this size are cheaper inline than through the pool
    # (thread wake-up costs more than a small write syscall)
    INLINE_WRITE_BYTES = 256 * 1024

    def _writer_pool(self) -> ThreadPoolExecutor:
        """Shared, lazily-created extraction pool.  One pool per EnvCache —
        thread spawn cost is paid once per node, not once per restore."""
        with self._flight_master:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    self.extract_threads,
                    thread_name_prefix="envcache-extract")
            return self._pool

    def close(self):
        with self._flight_master:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    def _data_path(self, key: str) -> str:
        return f"{self.base}/{key}.tar.{COMPRESSION}"

    def _meta_path(self, key: str) -> str:
        return f"{self.base}/{key}.meta.json"

    def exists(self, key: str) -> bool:
        return self.mount.exists(self._data_path(key)) and \
            self.mount.exists(self._meta_path(key))

    def expire(self, key: str):
        """Delete ``key``'s DFS archive + meta AND every local trace of it:
        the in-memory meta cache and any node-local cached archive for the
        key (all content-addressed generations).  Skipping either would let
        a re-snapshot under the same job key restore a stale environment."""
        for p in (self._data_path(key), self._meta_path(key)):
            if self.mount.exists(p):
                self.mount.hdfs.delete(self.mount._full(p))
        with self._flight_master:
            self._meta_cache.pop(key, None)
            # retire the key's flight lock too: without this the
            # per-key map grows for every job key ever restored
            self._in_flight.pop(key, None)
        if self._local is not None:
            self._local.invalidate_prefix(f"{key}.")

    # ----- create (first run, node 0) -----

    def create(self, key: str, target: str | Path, before: dict,
               job_params: Optional[dict] = None, *, striped: bool = True,
               launch_profile: Optional[dict] = None) -> dict:
        """Capture the diff of ``target`` vs ``before`` and upload.

        ``launch_profile``: a validated launch-env snapshot
        (``repro.tune.launchprofile.LaunchProfile.to_json()``) stored in
        the meta — every later restore hands it back so the runtime can
        diff the live environment and report drift."""
        target = Path(target)
        after = snapshot_dir(target)
        changed = diff_snapshots(before, after)
        buf = io.BytesIO()
        with tarfile.open(fileobj=buf, mode="w") as tar:
            for rel in changed:
                tar.add(target / rel, arcname=rel)
        raw = buf.getvalue()
        packed = _compress(raw)
        self.mount.write(self._data_path(key), packed, striped=striped,
                         placement=self.placement)
        meta = {"key": key, "files": len(changed),
                "raw_bytes": len(raw), "packed_bytes": len(packed),
                # content address of this archive generation: node-local
                # cache entries are keyed by it, so a re-snapshot under
                # the SAME job key can never be served a stale archive
                "digest": hashlib.sha256(packed).hexdigest(),
                "compression": COMPRESSION, "created": time.time(),
                "job_params": job_params or {}}
        if launch_profile is not None:
            meta["launch_profile"] = launch_profile
        self.mount.write(self._meta_path(key),
                         json.dumps(meta).encode())
        with self._flight_master:
            self._meta_cache[key] = meta
        if self._local is not None:
            # stale generations of this key are garbage now (expire may
            # not have run on this node before the re-create)
            for stale in self._local.keys():
                if stale.startswith(f"{key}.") \
                        and stale != self._entry_key(key, meta):
                    self._local.invalidate(stale)
        return meta

    # ----- restore (subsequent runs, every node) -----

    @staticmethod
    def _entry_key(key: str, meta: Optional[dict]) -> str:
        """Content-addressed node-cache key for one archive generation."""
        digest = (meta or {}).get("digest", "v0")[:16]
        return f"{key}.{digest}.tar.{COMPRESSION}"

    def _local_path(self, key: str, meta: Optional[dict] = None) -> Path:
        """Node-local path of ``key``'s cached archive.  Without ``meta``,
        resolves the (single live) generation by prefix — a test/debug
        convenience; the restore path always passes the meta through."""
        assert self._local is not None
        if meta is None:
            for k in self._local.keys():
                if k.startswith(f"{key}."):
                    return self._local.path(k)
        return self._local.path(self._entry_key(key, meta))

    def _key_lock(self, key: str) -> threading.Lock:
        with self._flight_master:
            return self._in_flight.setdefault(key, threading.Lock())

    def _fetch_archive(self, key: str, priority: int = 0) -> BinaryIO:
        """DFS fetch of the packed blob: width-way-parallel windowed reads."""
        handle = self.mount.open(self._data_path(key))
        with self._flight_master:
            self.stats["dfs_archive_fetches"] += 1
        return _WindowedReader(handle, len(handle), self.fetch_window,
                               sched=self.sched, priority=priority)

    def _open_archive(self, key: str, meta: Optional[dict],
                      priority: int = 0) -> BinaryIO:
        """Packed-archive byte stream: node-cache entry when enabled (one
        DFS fetch per node — the cache's singleflight admission), direct
        DFS stream otherwise."""
        if self._local is None:
            return self._fetch_archive(key, priority)

        def producer(tmp: Path):
            src = self._fetch_archive(key, priority)
            with open(tmp, "wb") as out:
                while True:
                    chunk = src.read(self.fetch_window)
                    if not chunk:
                        break
                    out.write(chunk)

        # under a byte bound, another key's admission can evict this entry
        # between fetch_path returning and the open — an eviction race is
        # a miss, so retry once and then stream straight from the DFS
        for _attempt in range(2):
            path, hit = self._local.fetch_path(self._entry_key(key, meta),
                                               producer)
            try:
                handle = open(path, "rb")
            except FileNotFoundError:
                continue
            if hit:
                with self._flight_master:
                    self.stats["local_cache_hits"] += 1
            return handle
        return self._fetch_archive(key, priority)

    def _extract_stream(self, packed: BinaryIO, target: Path):
        """Stream-decompress ``packed`` and extract members as they arrive.

        Large file payloads fan out to the shared writer pool (the write
        syscall releases the GIL); small ones are written inline — a thread
        hand-off costs more than the write itself."""
        target.mkdir(parents=True, exist_ok=True)
        futures = []
        try:
            with _decompress_stream(packed) as raw, \
                    tarfile.open(fileobj=raw, mode="r|") as tar:
                for member in tar:
                    _check_member(member)
                    if member.isdir():
                        (target / member.name).mkdir(parents=True,
                                                     exist_ok=True)
                    elif member.isreg():
                        src = tar.extractfile(member)
                        data = src.read() if src is not None else b""
                        if len(data) >= self.INLINE_WRITE_BYTES:
                            futures.append(self._writer_pool().submit(
                                _write_member, target, member, data))
                        else:
                            _write_member(target, member, data)
                    elif member.issym():
                        dest = target / member.name
                        dest.parent.mkdir(parents=True, exist_ok=True)
                        dest.unlink(missing_ok=True)
                        os.symlink(member.linkname, dest)
                    # hard links / other exotic types never come out of
                    # create()
        except BaseException:
            # drain in-flight writes before propagating: a retry (corrupt
            # local archive) must not race stale writes into the target
            for f in futures:
                try:
                    f.result()
                except Exception:  # noqa: BLE001 - original error wins
                    pass
            raise
        for f in futures:
            f.result()

    def restore(self, key: str, target: str | Path,
                priority: int = 0) -> Optional[dict]:
        """Extract the cached environment into ``target``.  Returns the cache
        meta, or None when no valid cache exists (caller falls back to the
        real install commands).  ``priority`` is the scheduler class the
        DFS window fetches run under (CRITICAL on the startup path)."""
        if not self.exists(key):
            return None
        with self._flight_master:
            meta = self._meta_cache.get(key)
        if meta is None:
            # singleflight like the archive fetch: N concurrent restores
            # cost ONE meta read, not a racy handful (also keeps DFS
            # read-byte accounting deterministic for the benchmarks)
            with self._key_lock(key):
                with self._flight_master:
                    meta = self._meta_cache.get(key)
                if meta is None:
                    meta = json.loads(
                        self.mount.open(self._meta_path(key)).read())
                    with self._flight_master:
                        self._meta_cache[key] = meta
            # meta is cached now: future restores take the fast path, so
            # the flight lock has done its job — stragglers already
            # blocked on the old lock object re-check the cache under it
            with self._flight_master:
                self._in_flight.pop(key, None)
        packed = self._open_archive(key, meta, priority)
        try:
            try:
                self._extract_stream(packed, Path(target))
            except Exception:
                if self._local is None:
                    raise
                # node-local archive may be corrupt (torn write, disk rot):
                # invalidate it and retry once straight from the DFS — only
                # a second failure (bad DFS copy) propagates
                packed.close()
                self._local.invalidate(self._entry_key(key, meta))
                packed = self._fetch_archive(key, priority)
                self._extract_stream(packed, Path(target))
        finally:
            packed.close()
        return meta
