"""Striped layout for checkpoint files (BootSeer §4.4, Fig. 11).

The logical file is split into 1 MB chunks; chunks are grouped into 4 MB
stripe units and the units round-robin across ``width`` physical files, each
placed in a DIFFERENT DataNode group.  Reads and writes therefore run with
``width``-way parallelism (one thread per physical file) instead of being
serialized inside a single 512 MB HDFS block.

Layout math for chunk ``i`` (chunk = 1 MB, stripe = 4 MB = ``spc`` chunks):
    unit        u = i // spc
    file        f = u % width
    unit-in-file  = u // width
    offset-in-file = (u // width) * stripe + (i % spc) * chunk

``StripedReader.pread`` reads an arbitrary (offset, length) range touching
only the chunks it needs — this is what makes *sharding-aware* checkpoint
resumption possible (each host fetches only its shard's byte ranges).
"""

from __future__ import annotations

import json
import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Optional

from repro.dfs.hdfs import BlockMeta, HdfsCluster

CHUNK = 1 * 1024 * 1024
STRIPE = 4 * 1024 * 1024


@dataclass(frozen=True)
class StripedMeta:
    size: int
    width: int
    chunk: int
    stripe: int
    files: tuple  # (group, name) per physical file

    @property
    def spc(self) -> int:  # chunks per stripe unit
        return self.stripe // self.chunk

    def locate(self, chunk_idx: int) -> tuple[int, int]:
        """-> (file index, offset within that physical file)."""
        u = chunk_idx // self.spc
        f = u % self.width
        off = (u // self.width) * self.stripe + (chunk_idx % self.spc) * self.chunk
        return f, off


class StripedWriter:
    """Parallel striped write of a logical stream."""

    def __init__(self, hdfs: HdfsCluster, path: str, *, width: int = 8,
                 chunk: int = CHUNK, stripe: int = STRIPE,
                 threads: Optional[int] = None):
        assert stripe % chunk == 0
        self.hdfs = hdfs
        self.path = path
        self.width = min(width, hdfs.num_groups)
        self.chunk = chunk
        self.stripe = stripe
        self.threads = threads or self.width
        self._buf = bytearray()
        self._size = 0
        self._flushed = 0
        self._files = []
        self._handles = []
        import zlib
        tag = zlib.crc32(path.encode()) % 10 ** 8
        for f in range(self.width):
            group = (f * max(hdfs.num_groups // self.width, 1)) % hdfs.num_groups
            name = f"stripe_{tag:08d}_{f}"
            self._files.append((group, name))
            self._handles.append(hdfs.open_group_file(group, name, "wb"))
        self._lock = threading.Lock()

    def write(self, data: bytes):
        self._buf += data
        self._size += len(data)
        full = (len(self._buf) // self.chunk) * self.chunk
        if full:
            self._flush(bytes(self._buf[:full]))
            del self._buf[:full]

    def _flush(self, data: bytes):
        meta = self._meta_for(self._size)  # width/chunk/stripe fixed
        start_chunk = self._flushed // self.chunk
        self._flushed += len(data)
        # group chunk writes per file, then write in parallel
        per_file: dict[int, list[tuple[int, bytes]]] = {}
        for j in range(0, len(data), self.chunk):
            ci = start_chunk + j // self.chunk
            f, off = meta.locate(ci)
            per_file.setdefault(f, []).append((off, data[j:j + self.chunk]))

        def write_file(f):
            h = self._handles[f]
            for off, payload in per_file[f]:
                h.seek(off)
                h.write(payload)
            if self.hdfs.throttle:
                n = sum(len(p) for _, p in per_file[f])
                with self.hdfs.throttle:
                    self.hdfs.throttle.charge(n)

        # size the pool to the files actually touched; a single-file flush
        # (small archives) runs inline instead of spinning up threads
        if len(per_file) == 1:
            write_file(next(iter(per_file)))
        else:
            with ThreadPoolExecutor(min(self.threads, len(per_file))) as ex:
                list(ex.map(write_file, per_file))

    def _meta_for(self, size: int) -> StripedMeta:
        return StripedMeta(size=size, width=self.width, chunk=self.chunk,
                           stripe=self.stripe, files=tuple(self._files))

    def close(self):
        if self._buf:
            pad = bytes(self._buf)
            self._buf.clear()
            self._flush(pad + b"\0" * ((-len(pad)) % self.chunk))
        for h in self._handles:
            h.close()
        meta = self._meta_for(self._size)
        blocks = [BlockMeta(group=g, path=n, length=0)
                  for g, n in meta.files]
        self.hdfs.register_raw(
            self.path, self._size, blocks,
            attrs={"striped": {
                "size": meta.size, "width": meta.width, "chunk": meta.chunk,
                "stripe": meta.stripe, "files": list(meta.files)}})

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class StripedReader:
    """Parallel positional reads of a striped file."""

    def __init__(self, hdfs: HdfsCluster, path: str,
                 threads: Optional[int] = None):
        self.hdfs = hdfs
        raw = hdfs.attrs(path)["striped"]
        self.meta = StripedMeta(size=raw["size"], width=raw["width"],
                                chunk=raw["chunk"], stripe=raw["stripe"],
                                files=tuple(tuple(f) for f in raw["files"]))
        self.threads = threads or self.meta.width

    @property
    def size(self) -> int:
        return self.meta.size

    def pread(self, offset: int, length: int) -> bytes:
        m = self.meta
        length = min(length, m.size - offset)
        if length <= 0:
            return b""
        first = offset // m.chunk
        last = (offset + length - 1) // m.chunk
        # gather the chunk reads, grouped per physical file
        jobs: dict[int, list[tuple[int, int, int, int]]] = {}
        for ci in range(first, last + 1):
            f, base = m.locate(ci)
            lo = max(offset - ci * m.chunk, 0)
            hi = min(offset + length - ci * m.chunk, m.chunk)
            dst = ci * m.chunk + lo - offset
            jobs.setdefault(f, []).append((base + lo, hi - lo, dst, ci))

        out = bytearray(length)

        def read_file(f):
            group, name = m.files[f]
            n = 0
            with self.hdfs.open_group_file(group, name, "rb") as h:
                for off, ln, dst, _ in jobs[f]:
                    h.seek(off)
                    out[dst:dst + ln] = h.read(ln)
                    n += ln
            if self.hdfs.throttle:
                with self.hdfs.throttle:
                    self.hdfs.throttle.charge(n)

        # single-file reads (sub-stripe ranges) skip the pool entirely
        if len(jobs) == 1:
            read_file(next(iter(jobs)))
        else:
            with ThreadPoolExecutor(min(self.threads, len(jobs))) as ex:
                list(ex.map(read_file, jobs))
        return bytes(out)

    def read_all(self) -> bytes:
        return self.pread(0, self.meta.size)


def write_striped(hdfs: HdfsCluster, path: str, data: bytes, *,
                  width: int = 8, chunk: int = CHUNK, stripe: int = STRIPE):
    with StripedWriter(hdfs, path, width=width, chunk=chunk,
                       stripe=stripe) as w:
        w.write(data)
