"""Striped layout for checkpoint files (BootSeer §4.4, Fig. 11) behind the
storage fabric's :class:`~repro.fabric.placement.Placement` abstraction.

The logical file is split into 1 MB chunks; chunks are grouped into 4 MB
stripe units and the units round-robin across ``width`` physical files, each
placed in a DIFFERENT DataNode group.  Reads and writes therefore run with
``width``-way parallelism (one thread per physical file) instead of being
serialized inside a single 512 MB HDFS block.

Layout math for chunk ``i`` (chunk = 1 MB, stripe = 4 MB = ``spc`` chunks):
    unit        u = i // spc
    file        f = u % width
    unit-in-file  = u // width
    offset-in-file = (u // width) * stripe + (i % spc) * chunk

``StripedReader.pread`` reads an arbitrary (offset, length) range touching
only the chunks it needs — this is what makes *sharding-aware* checkpoint
resumption possible (each host fetches only its shard's byte ranges).
``StripedReader.pread_many`` batches a whole *set* of ranges (a restore
plan's reads, see repro.ckpt.plan): all chunk sub-reads are grouped per
physical stripe file, each file is opened AT MOST ONCE per call, and the
per-file jobs run on one shared long-lived I/O pool instead of a fresh
``ThreadPoolExecutor`` per read.

Durability is a placement property, not a reader property:

* ``striped`` (default) — the pre-fabric behaviour, byte-identical
  layout and metadata: a missing/truncated physical file raises
  :class:`StripeMissingError` naming the file and DataNode group.
* ``replicated`` — a failed data file fails over to its mirror copies.
* ``erasure`` — Reed-Solomon parity files; a missing or truncated data
  file is **reconstructed transparently** inside ``pread_many`` (the
  caller sees correct bytes), a *corrupted* chunk (bad bytes, right
  length) is detected by its stored CRC and reconstructed too.
  Reconstruction I/O runs under the reader's ``IOScheduler`` priority
  and lands in the cluster's byte accounting like any other read; the
  reader's ``stats`` (and ``HdfsCluster.fabric_stats``) count
  ``degraded_reads`` / ``reconstructed_bytes`` /
  ``reconstruction_read_bytes`` / ``corrupt_chunks``.
"""

from __future__ import annotations

import os
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.dfs.hdfs import BlockMeta, HdfsCluster
from repro.fabric.gf256 import cauchy_matrix, gf_mul_bytes, rs_decode
from repro.fabric.placement import ERASURE, REPLICATED, Placement

CHUNK = 1 * 1024 * 1024
STRIPE = 4 * 1024 * 1024


class StripeMissingError(RuntimeError):
    """A physical stripe file backing a striped DFS file is missing (or
    truncated): the logical file cannot be read completely.  Raised instead
    of returning silently corrupt bytes; names the exact physical file and
    DataNode group so operators know which replica to repair."""

    def __init__(self, logical_path: str, *, file_index: int, group: int,
                 name: str, detail: str = "missing"):
        self.logical_path = logical_path
        self.file_index = file_index
        self.group = group
        self.name = name
        super().__init__(
            f"striped file '{logical_path}': physical stripe file '{name}' "
            f"(stripe index {file_index}, DataNode group {group}) is "
            f"{detail}")


class StripeCorruptError(StripeMissingError):
    """A stripe chunk failed its stored CRC (bad bytes, correct length)
    and could not be reconstructed from parity — detected by the erasure
    placement's per-chunk digests instead of being returned as payload."""

    def __init__(self, logical_path: str, *, file_index: int, group: int,
                 name: str, detail: str = "corrupt"):
        super().__init__(logical_path, file_index=file_index, group=group,
                         name=name, detail=detail)


def pread_many_fallback(pread, ranges, into=None, priority=None):
    """Per-range ``pread_many`` for non-striped readers, matching
    ``StripedReader.pread_many``'s return contract (bytes list, or byte
    counts with ``into`` buffers filled).  Independent ranges run
    concurrently on the shared I/O pool, so the plain path keeps the
    multi-tensor fetch parallelism the old restore had.  ``priority`` is
    accepted for signature parity with ``StripedReader.pread_many`` (the
    plain path is not scheduler-metered)."""
    results: list = [None] * len(ranges)

    def one(i):
        off, ln = ranges[i]
        data = pread(off, ln)
        if into is None:
            results[i] = data
        else:
            memoryview(into[i])[:len(data)] = data
            results[i] = len(data)

    if len(ranges) <= 1:
        for i in range(len(ranges)):
            one(i)
    else:
        pool = shared_io_pool()
        for fu in [pool.submit(one, i) for i in range(len(ranges))]:
            fu.result()
    return results


_IO_POOL: Optional[ThreadPoolExecutor] = None
_IO_POOL_LOCK = threading.Lock()


def shared_io_pool() -> ThreadPoolExecutor:
    """Process-wide long-lived pool for striped-DFS file jobs.

    Every reader/writer shares it, so the per-call executor spawn cost
    (~ms per thread on small boxes) is paid once per process and total
    I/O concurrency stays bounded by the pool size instead of scaling
    with the number of concurrent readers.  Tasks submitted here are pure
    file I/O and never wait on other tasks in this pool, so it cannot
    deadlock.
    """
    global _IO_POOL
    with _IO_POOL_LOCK:
        if _IO_POOL is None:
            _IO_POOL = ThreadPoolExecutor(
                max(4, 2 * (os.cpu_count() or 2)),
                thread_name_prefix="dfs-io")
        return _IO_POOL


@dataclass(frozen=True)
class StripedMeta:
    size: int
    width: int
    chunk: int
    stripe: int
    files: tuple  # (group, name) per physical file

    @property
    def spc(self) -> int:  # chunks per stripe unit
        return self.stripe // self.chunk

    def locate(self, chunk_idx: int) -> tuple[int, int]:
        """-> (file index, offset within that physical file)."""
        u = chunk_idx // self.spc
        f = u % self.width
        off = (u // self.width) * self.stripe + (chunk_idx % self.spc) * self.chunk
        return f, off


class StripedWriter:
    """Parallel striped write of a logical stream, placement-aware.

    ``placement``: a :class:`repro.fabric.placement.Placement` (or its
    kind string).  ``replicated`` mirrors every data-file write through
    to its replica handles; ``erasure`` accumulates Reed-Solomon parity
    byte-wise (at identical file offsets, so no stripe-row alignment is
    needed on read) plus a CRC per written chunk, and writes the parity
    files at :meth:`close`.  Plain striping writes byte-identical data
    AND metadata to the pre-fabric format.
    """

    def __init__(self, hdfs: HdfsCluster, path: str, *, width: int = 8,
                 chunk: int = CHUNK, stripe: int = STRIPE,
                 threads: Optional[int] = None,
                 placement: Placement | str | None = None):
        assert stripe % chunk == 0
        self.hdfs = hdfs
        self.path = path
        self.width = min(width, hdfs.num_groups)
        self.chunk = chunk
        self.stripe = stripe
        self.threads = threads or self.width
        self.placement = Placement.parse(placement)
        self._buf = bytearray()
        self._size = 0
        self._flushed = 0
        self._files = []
        self._handles = []
        tag = zlib.crc32(path.encode()) % 10 ** 8
        for f in range(self.width):
            group = (f * max(hdfs.num_groups // self.width, 1)) % hdfs.num_groups
            name = f"stripe_{tag:08d}_{f}"
            self._files.append((group, name))
            self._handles.append(hdfs.open_group_file(group, name, "wb"))
        self._file_len = [0] * self.width          # bytes written per file
        # replicated: mirror handles per data file
        self._replicas: list[list[tuple[int, str]]] = []
        self._replica_handles: list[list] = []
        if self.placement.kind == REPLICATED:
            # region-spread: each mirror jumps a whole region of groups,
            # so replica r lives in region (data_region + r + 1) — a
            # lost region still leaves a full copy, and a remote
            # region's reader fails over to a region-LOCAL mirror.
            # Falls back to the adjacent-group layout on single-region
            # clusters (where the stride would degenerate to 0 mod n).
            spread = (self.placement.region_spread
                      and hdfs.num_regions > 1)
            stride = hdfs.region_stride() if spread else 1
            for f in range(self.width):
                names, handles = [], []
                for r in range(self.placement.replicas):
                    group = (self._files[f][0]
                             + (r + 1) * stride) % hdfs.num_groups
                    if group == self._files[f][0]:
                        # stride wrapped a full lap (replicas >= regions
                        # or >= groups): never mirror into the data
                        # file's own group
                        group = (group + 1) % hdfs.num_groups
                    name = f"stripe_{tag:08d}_{f}r{r}"
                    names.append((group, name))
                    handles.append(hdfs.open_group_file(group, name, "wb"))
                self._replicas.append(names)
                self._replica_handles.append(handles)
        # erasure: byte-wise parity accumulators + per-chunk CRCs
        self._parity_arr: list[np.ndarray] = []
        self._coef: list[list[int]] = []
        self._crcs: list[dict[int, int]] = [dict() for _ in range(self.width)]
        if self.placement.kind == ERASURE:
            self._coef = cauchy_matrix(self.placement.parity, self.width)
            self._parity_arr = [np.zeros(0, np.uint8)
                                for _ in range(self.placement.parity)]

    def write(self, data: bytes):
        self._buf += data
        self._size += len(data)
        full = (len(self._buf) // self.chunk) * self.chunk
        if full:
            self._flush(bytes(self._buf[:full]))
            del self._buf[:full]

    def _ensure_parity(self, nbytes: int):
        for j, arr in enumerate(self._parity_arr):
            if len(arr) < nbytes:
                grown = np.zeros(max(nbytes, 2 * len(arr)), np.uint8)
                grown[:len(arr)] = arr
                self._parity_arr[j] = grown

    def _flush(self, data: bytes):
        meta = self._meta_for(self._size)  # width/chunk/stripe fixed
        start_chunk = self._flushed // self.chunk
        self._flushed += len(data)
        # group chunk writes per file, then write in parallel
        per_file: dict[int, list[tuple[int, bytes]]] = {}
        for j in range(0, len(data), self.chunk):
            ci = start_chunk + j // self.chunk
            f, off = meta.locate(ci)
            payload = data[j:j + self.chunk]
            per_file.setdefault(f, []).append((off, payload))
            self._file_len[f] = max(self._file_len[f], off + len(payload))
            if self.placement.kind == ERASURE:
                self._crcs[f][off // self.chunk] = zlib.crc32(payload)
                self._ensure_parity(off + len(payload))
                src = np.frombuffer(payload, np.uint8)
                for p, row in enumerate(self._coef):
                    dst = self._parity_arr[p][off:off + len(payload)]
                    np.bitwise_xor(dst, gf_mul_bytes(row[f], src), out=dst)

        def write_file(f):
            h = self._handles[f]
            n = 0
            for off, payload in per_file[f]:
                h.seek(off)
                h.write(payload)
                n += len(payload)
            for rh in (self._replica_handles[f] if self._replica_handles
                       else ()):
                for off, payload in per_file[f]:
                    rh.seek(off)
                    rh.write(payload)
                    n += len(payload)
            self.hdfs.account_write(n)
            if self.hdfs.throttle:
                with self.hdfs.throttle:
                    self.hdfs.throttle.charge(n)

        # a single-file flush (small archives) runs inline instead of
        # round-tripping through the pool
        if len(per_file) == 1:
            write_file(next(iter(per_file)))
        else:
            pool = shared_io_pool()
            for fu in [pool.submit(write_file, f) for f in per_file]:
                fu.result()

    def _meta_for(self, size: int) -> StripedMeta:
        return StripedMeta(size=size, width=self.width, chunk=self.chunk,
                           stripe=self.stripe, files=tuple(self._files))

    def _close_parity(self) -> Placement:
        """Write the parity files and return the fully-populated
        erasure Placement record."""
        tag = zlib.crc32(self.path.encode()) % 10 ** 8
        parity_len = max(self._file_len) if any(self._file_len) else 0
        parity_files, parity_crcs = [], []
        for p in range(self.placement.parity):
            group = (self.width + p) % self.hdfs.num_groups
            name = f"stripe_{tag:08d}_p{p}"
            parity_files.append((group, name))
            buf = self._parity_arr[p][:parity_len]
            with self.hdfs.open_group_file(group, name, "wb") as h:
                h.write(buf.tobytes())
            self.hdfs.account_write(parity_len)
            if self.hdfs.throttle:
                with self.hdfs.throttle:
                    self.hdfs.throttle.charge(parity_len)
            parity_crcs.append(
                [zlib.crc32(buf[o:o + self.chunk])
                 for o in range(0, parity_len, self.chunk)])
        data_crcs = [[crcs[i] for i in sorted(crcs)] for crcs in self._crcs]
        return Placement(
            kind=ERASURE, parity=self.placement.parity,
            verify=self.placement.verify,
            parity_files=tuple(parity_files),
            file_lengths=tuple(self._file_len), parity_length=parity_len,
            chunk_crc={"data": data_crcs, "parity": parity_crcs})

    def close(self):
        if self._buf:
            pad = bytes(self._buf)
            self._buf.clear()
            self._flush(pad + b"\0" * ((-len(pad)) % self.chunk))
        for h in self._handles:
            h.close()
        for handles in self._replica_handles:
            for h in handles:
                h.close()
        placement = self.placement
        if placement.kind == ERASURE:
            placement = self._close_parity()
        elif placement.kind == REPLICATED:
            placement = Placement(kind=REPLICATED,
                                  replicas=placement.replicas,
                                  region_spread=placement.region_spread,
                                  replica_files=tuple(
                                      tuple(r) for r in self._replicas))
        meta = self._meta_for(self._size)
        blocks = [BlockMeta(group=g, path=n, length=0)
                  for g, n in meta.files]
        attrs = {"striped": {
            "size": meta.size, "width": meta.width, "chunk": meta.chunk,
            "stripe": meta.stripe, "files": list(meta.files)}}
        placement_attrs = placement.to_attrs()
        if placement_attrs is not None:
            attrs["placement"] = placement_attrs
        self.hdfs.register_raw(self.path, self._size, blocks, attrs=attrs)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class StripedReader:
    """Parallel positional reads of a striped file.

    All read paths funnel through :meth:`pread_many`: sub-reads are grouped
    per physical stripe file, sorted and merged into sequential runs, each
    file is opened at most once per call, and the per-file jobs run on the
    shared long-lived I/O pool (``threads`` is kept for API compat but the
    pool bounds actual concurrency).

    The file's :class:`Placement` (recorded at write time) decides what a
    failed physical file means: plain striping raises
    :class:`StripeMissingError` exactly as before the fabric; replication
    fails over to mirror copies; erasure placement reconstructs the
    missing/corrupt chunks from parity inside this call.
    """

    def __init__(self, hdfs: HdfsCluster, path: str,
                 threads: Optional[int] = None,
                 pool: Optional[ThreadPoolExecutor] = None,
                 sched=None, priority: int = 0,
                 prefer_region: Optional[int] = None):
        self.hdfs = hdfs
        self.path = path
        # region-local reads: with region-spread replicated placement, a
        # reader in DataNode region ``prefer_region`` serves each stripe
        # from whichever copy (primary or mirror) lives in its own
        # region, so a remote region's restore never crosses the WAN for
        # data it has a local mirror of.  None keeps primary-first.
        self.prefer_region = prefer_region
        attrs = hdfs.attrs(path)
        raw = attrs["striped"]
        self.meta = StripedMeta(size=raw["size"], width=raw["width"],
                                chunk=raw["chunk"], stripe=raw["stripe"],
                                files=tuple(tuple(f) for f in raw["files"]))
        self.placement = Placement.from_attrs(attrs.get("placement"))
        self.threads = threads or self.meta.width
        self._pool = pool
        # optional bandwidth-aware scheduler (repro.core.pipeline
        # IOScheduler): each per-file read job holds one "dfs" token, so
        # concurrent readers of different priority classes cannot convoy
        # each other — a CRITICAL params-wave pread is granted the next
        # free token even when a DEFERRED opt-state wave queued first
        self.sched = sched
        self.priority = priority
        self.stats = {"degraded_reads": 0, "reconstructed_bytes": 0,
                      "reconstruction_read_bytes": 0, "corrupt_chunks": 0}

    @property
    def size(self) -> int:
        return self.meta.size

    def pread(self, offset: int, length: int) -> bytes:
        return self.pread_many([(offset, length)])[0]

    def _account_fabric(self, **kw):
        for key, n in kw.items():
            self.stats[key] += n
        self.hdfs.account_fabric(**kw)

    def pread_many(self, ranges: Sequence[tuple[int, int]],
                   into: Optional[Sequence] = None,
                   priority: Optional[int] = None):
        """Batched positional reads.

        ``ranges``: (offset, length) pairs over the logical stream; each is
        clamped at EOF like :meth:`pread`.  Without ``into``, returns one
        ``bytes`` per range.  With ``into`` — parallel writable buffers
        (anything supporting the buffer protocol, e.g. numpy uint8 views) —
        bytes land zero-copy via ``readinto`` and the per-range byte counts
        are returned.  ``priority`` overrides the reader's scheduler
        priority class for this call (ignored without a scheduler).

        Raises :class:`StripeMissingError` if a physical stripe file is
        gone or short *and the placement cannot recover it* (plain
        striping never can; replication/erasure raise only past their
        failure budget).
        """
        m = self.meta
        prio = self.priority if priority is None else priority
        clamped: list[tuple[int, int]] = []
        views: list[Optional[memoryview]] = []
        out: list = []
        for i, (off, ln) in enumerate(ranges):
            ln = max(0, min(ln, m.size - off))
            clamped.append((off, ln))
            if into is None:
                buf = bytearray(ln)
                out.append(buf)
                views.append(memoryview(buf))
            else:
                out.append(ln)
                views.append(memoryview(into[i]) if ln else None)

        # chunk sub-reads grouped per physical file:
        # (file_offset, length, range_idx, dest_offset)
        jobs: dict[int, list[tuple[int, int, int, int]]] = {}
        for i, (off, ln) in enumerate(clamped):
            if ln <= 0:
                continue
            first = off // m.chunk
            last = (off + ln - 1) // m.chunk
            for ci in range(first, last + 1):
                f, base = m.locate(ci)
                lo = max(off - ci * m.chunk, 0)
                hi = min(off + ln - ci * m.chunk, m.chunk)
                dst = ci * m.chunk + lo - off
                jobs.setdefault(f, []).append((base + lo, hi - lo, i, dst))

        # sort by file offset and merge file- and dest-contiguous sub-reads
        # so full-tensor restores become a few big sequential readintos
        for f, subs in jobs.items():
            subs.sort()
            merged = [subs[0]]
            for off, ln, i, dst in subs[1:]:
                poff, pln, pi, pdst = merged[-1]
                if off == poff + pln and i == pi and dst == pdst + pln:
                    merged[-1] = (poff, pln + ln, pi, pdst)
                else:
                    merged.append((off, ln, i, dst))
            jobs[f] = merged

        if self.placement.kind == ERASURE:
            self._pread_erasure(jobs, views, prio)
        else:
            self._pread_direct(jobs, views, prio)
        if into is None:
            return [bytes(b) for b in out]
        return out

    # ----- striped / replicated path -----------------------------------

    def _pread_direct(self, jobs, views, prio):
        def read_file(f):
            if self.sched is not None:
                nbytes = sum(ln for _, ln, _, _ in jobs[f])
                with self.sched.slot("dfs", priority=prio, nbytes=nbytes):
                    return read_file_inner(f)
            return read_file_inner(f)

        def read_file_inner(f):
            group, name = self.meta.files[f]
            candidates = [(group, name)]
            if self.placement.kind == REPLICATED:
                replicas = (self.placement.replica_files[f]
                            if f < len(self.placement.replica_files) else ())
                candidates += [tuple(r) for r in replicas]
                if self.prefer_region is not None:
                    # region-local copies first (stable: primary-before-
                    # mirror within each region class).  A mirror read
                    # chosen for locality is NOT a degraded read — only
                    # falling past a FAILED primary is.
                    candidates.sort(key=lambda gn: self.hdfs.group_region(
                        gn[0]) != self.prefer_region)
            primary_failed = False
            last_err = None
            for g, n in candidates:
                try:
                    self._read_subs(f, g, n, jobs[f], views)
                except StripeMissingError as err:
                    last_err = err
                    if (g, n) == (group, name):
                        primary_failed = True
                        if self.placement.kind != REPLICATED:
                            raise
                    continue
                if (g, n) != (group, name) and primary_failed:
                    self._account_fabric(degraded_reads=1)
                return
            raise StripeMissingError(
                self.path, file_index=f, group=group, name=name,
                detail=f"missing and all {len(candidates) - 1} replicas "
                       "are missing or truncated") from last_err

        # single-file calls (sub-stripe ranges) skip the pool entirely
        if len(jobs) == 1:
            read_file(next(iter(jobs)))
        elif jobs:
            pool = self._pool or shared_io_pool()
            futs = [pool.submit(read_file, f) for f in jobs]
            for fu in futs:
                fu.result()

    def _read_subs(self, f, group, name, subs, views):
        """One physical file's merged sub-reads straight into ``views``
        (the pre-fabric hot path, unchanged)."""
        n = 0
        try:
            h = self.hdfs.open_group_file(group, name, "rb")
        except FileNotFoundError as e:
            raise StripeMissingError(self.path, file_index=f,
                                     group=group, name=name) from e
        # accounting in finally: a truncated attempt has already moved
        # its partial bytes off the DataNode, and replica retries repeat
        # the cost — failed attempts bill like successful ones
        try:
            with h:
                for off, ln, i, dst in subs:
                    h.seek(off)
                    got = h.readinto(views[i][dst:dst + ln])
                    n += max(0, int(got or 0))
                    if got != ln:
                        raise StripeMissingError(
                            self.path, file_index=f, group=group, name=name,
                            detail=f"truncated (wanted {ln} bytes at offset "
                                   f"{off}, got {got})")
        finally:
            if n:
                self.hdfs.account_read(n)
                if self.hdfs.throttle:
                    with self.hdfs.throttle:
                        self.hdfs.throttle.charge(n)

    # ----- erasure path -------------------------------------------------

    @staticmethod
    def _rows_of(subs, chunk) -> list:
        rows = set()
        for off, ln, _i, _dst in subs:
            rows.update(range(off // chunk, (off + ln - 1) // chunk + 1))
        return sorted(rows)

    def _read_rows(self, group, name, rows, *, length, crcs, f_idx,
                   pad_missing=False):
        """Read whole chunk rows of one physical file.

        Returns ``(chunks: {row: np.uint8 array}, bad_rows: set)`` where
        ``bad_rows`` are rows whose CRC failed verification.  Rows past
        the recorded ``length`` are all-zero without touching disk when
        ``pad_missing`` (reconstruction sources: RS coding zero-pads the
        shorter data files).  Raises :class:`StripeMissingError` when the
        file itself is gone or shorter than its recorded length.
        """
        chunk = self.meta.chunk
        chunks: dict[int, np.ndarray] = {}
        bad: set[int] = set()
        disk_rows = []
        for r in rows:
            if (r + 1) * chunk > length:
                if not pad_missing:
                    raise StripeMissingError(
                        self.path, file_index=f_idx, group=group, name=name,
                        detail=f"chunk {r} beyond recorded length {length}")
                chunks[r] = np.zeros(chunk, np.uint8)
            else:
                disk_rows.append(r)
        # merge contiguous rows into sequential runs
        runs: list[list[int]] = []
        for r in disk_rows:
            if runs and runs[-1][-1] == r - 1:
                runs[-1].append(r)
            else:
                runs.append([r])
        n = 0
        if disk_rows:
            try:
                h = self.hdfs.open_group_file(group, name, "rb")
            except FileNotFoundError as e:
                raise StripeMissingError(self.path, file_index=f_idx,
                                         group=group, name=name) from e
            # bill in finally: a truncation detected mid-run has already
            # moved its partial bytes (same discipline as _read_subs)
            try:
                with h:
                    for run in runs:
                        buf = np.empty(len(run) * chunk, np.uint8)
                        h.seek(run[0] * chunk)
                        got = h.readinto(memoryview(buf))
                        n += max(0, int(got or 0))
                        if got != len(buf):
                            raise StripeMissingError(
                                self.path, file_index=f_idx, group=group,
                                name=name,
                                detail=f"truncated (wanted {len(buf)} bytes "
                                       f"at offset {run[0] * chunk}, "
                                       f"got {got})")
                        for j, r in enumerate(run):
                            chunks[r] = buf[j * chunk:(j + 1) * chunk]
            finally:
                if n:
                    self.hdfs.account_read(n)
                    if self.hdfs.throttle:
                        with self.hdfs.throttle:
                            self.hdfs.throttle.charge(n)
        if self.placement.verify and crcs is not None:
            for r in disk_rows:
                if r < len(crcs) and zlib.crc32(chunks[r]) != crcs[r]:
                    bad.add(r)
        return chunks, bad, n

    def _pread_erasure(self, jobs, views, prio):
        m = self.meta
        crc = self.placement.chunk_crc or {}
        data_crcs = crc.get("data", [])
        lengths = self.placement.file_lengths

        results: dict[int, dict[int, np.ndarray]] = {}
        failed: dict[int, set[int]] = {}

        verify = self.placement.verify

        def attempt(f):
            group, name = m.files[f]
            if not verify:
                # no CRCs to check: the healthy path reads exact ranges
                # like plain striping (zero read amplification); only a
                # failure falls back to chunk-row reconstruction
                try:
                    if self.sched is not None:
                        nb = sum(ln for _, ln, _, _ in jobs[f])
                        with self.sched.slot("dfs", priority=prio,
                                             nbytes=nb):
                            self._read_subs(f, group, name, jobs[f], views)
                    else:
                        self._read_subs(f, group, name, jobs[f], views)
                    return f, None, set()
                except StripeMissingError:
                    return f, {}, set(self._rows_of(jobs[f], m.chunk))
            rows = self._rows_of(jobs[f], m.chunk)
            crcs = data_crcs[f] if f < len(data_crcs) else None
            length = lengths[f] if f < len(lengths) else m.size
            nbytes = len(rows) * m.chunk

            def inner():
                try:
                    chunks, bad, _n = self._read_rows(
                        group, name, rows, length=length, crcs=crcs,
                        f_idx=f)
                except StripeMissingError:
                    return f, {}, set(rows)
                if bad:
                    self._account_fabric(corrupt_chunks=len(bad))
                return f, chunks, bad

            if self.sched is not None:
                with self.sched.slot("dfs", priority=prio, nbytes=nbytes):
                    return inner()
            return inner()

        if len(jobs) == 1:
            outs = [attempt(next(iter(jobs)))]
        else:
            pool = self._pool or shared_io_pool()
            outs = [fu.result()
                    for fu in [pool.submit(attempt, f) for f in jobs]]
        for f, chunks, bad in outs:
            results[f] = chunks
            if bad:
                failed[f] = set(bad)

        if failed:
            self._recover(failed, results, prio)
        for f, subs in jobs.items():
            # chunks=None marks a file already scattered zero-copy by the
            # exact-range path
            if results[f] is not None:
                self._scatter(results[f], subs, views)

    def _recover(self, failed: dict[int, set[int]],
                 results: dict[int, dict[int, np.ndarray]], prio):
        """Reconstruct the failed chunk rows from k surviving shards.

        ``failed`` maps data-file index -> rows lost (missing file,
        truncation, or CRC mismatch); reconstructed chunks are CRC-checked
        against the stored digests before being trusted.  Source reads
        hold DFS scheduler tokens at the caller's priority and land in
        normal read accounting — the measured read amplification of
        degraded mode.
        """
        m = self.meta
        k = m.width
        par = self.placement.parity
        crc = self.placement.chunk_crc or {}
        data_crcs = crc.get("data", [])
        parity_crcs = crc.get("parity", [])
        lengths = self.placement.file_lengths

        need_rows = sorted(set().union(*failed.values()))
        have: dict[int, dict[int, np.ndarray]] = {r: {} for r in need_rows}
        # seed with survivor chunks this very call already read (and CRC
        # verified): a planned restore sweeps all files at aligned
        # offsets, so most of the k source ranges per missing chunk are
        # in hand and reconstruction only fetches the gaps + parity —
        # read amplification ~1 + 1/k instead of 1 + (k-1)/k
        for f2, chunks in results.items():
            if f2 in failed or chunks is None:
                continue
            for r in need_rows:
                blk = chunks.get(r)
                if blk is not None:
                    have[r][f2] = blk
        # exclude any shard with failures from the source pool entirely:
        # with k+m shards and <= m failures there are always >= k clean
        # candidates, and a partially-corrupt source is not worth the
        # bookkeeping of per-row trust
        candidates = ([f for f in range(k) if f not in failed]
                      + [k + j for j in range(par)])
        src_bytes = 0
        for shard in candidates:
            missing = [r for r in need_rows
                       if len(have[r]) < k and shard not in have[r]]
            if not missing:
                if all(len(have[r]) >= k for r in need_rows):
                    break
                continue
            if shard < k:
                group, name = m.files[shard]
                crcs = data_crcs[shard] if shard < len(data_crcs) else None
                length = lengths[shard] if shard < len(lengths) else 0
            else:
                j = shard - k
                if j >= len(self.placement.parity_files):
                    continue
                group, name = self.placement.parity_files[j]
                crcs = parity_crcs[j] if j < len(parity_crcs) else None
                length = self.placement.parity_length
            nbytes = len(missing) * m.chunk

            def read_source():
                try:
                    chunks, bad, n = self._read_rows(
                        group, name, missing, length=length, crcs=crcs,
                        f_idx=shard, pad_missing=True)
                except StripeMissingError:
                    return {}, 0
                return ({r: c for r, c in chunks.items() if r not in bad},
                        n)

            if self.sched is not None:
                with self.sched.slot("dfs", priority=prio, nbytes=nbytes):
                    good, n = read_source()
            else:
                good, n = read_source()
            src_bytes += n
            for r, blk in good.items():
                have[r][shard] = blk

        recon_bytes = 0
        for r in need_rows:
            want = [f for f in failed if r in failed[f]]
            if len(have[r]) < k:
                group, name = m.files[want[0]]
                raise StripeMissingError(
                    self.path, file_index=want[0], group=group, name=name,
                    detail=f"unrecoverable: chunk {r} has only "
                           f"{len(have[r])} of the k={k} source shards "
                           f"needed (parity m={par} exhausted)")
            decoded = rs_decode(have[r], k, par, want)
            for f in want:
                blk = decoded[f]
                crcs = data_crcs[f] if f < len(data_crcs) else None
                if (self.placement.verify and crcs is not None
                        and r < len(crcs)
                        and zlib.crc32(blk) != crcs[r]):
                    group, name = m.files[f]
                    raise StripeCorruptError(
                        self.path, file_index=f, group=group, name=name,
                        detail=f"chunk {r} reconstruction failed its "
                               "stored CRC (more corrupt shards than "
                               "parity can absorb)")
                results[f][r] = blk
                recon_bytes += len(blk)
        self._account_fabric(degraded_reads=len(failed),
                             reconstructed_bytes=recon_bytes,
                             reconstruction_read_bytes=src_bytes)

    def _scatter(self, chunks: dict[int, np.ndarray], subs, views):
        c = self.meta.chunk
        for off, ln, i, dst in subs:
            for r in range(off // c, (off + ln - 1) // c + 1):
                blk = chunks[r]
                lo = max(off - r * c, 0)
                hi = min(off + ln - r * c, c)
                views[i][dst + (r * c + lo - off):
                         dst + (r * c + hi - off)] = memoryview(blk[lo:hi])

    def read_all(self) -> bytes:
        return self.pread(0, self.meta.size)


def write_striped(hdfs: HdfsCluster, path: str, data: bytes, *,
                  width: int = 8, chunk: int = CHUNK, stripe: int = STRIPE,
                  placement: Placement | str | None = None):
    with StripedWriter(hdfs, path, width=width, chunk=chunk,
                       stripe=stripe, placement=placement) as w:
        w.write(data)
