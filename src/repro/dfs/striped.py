"""Striped layout for checkpoint files (BootSeer §4.4, Fig. 11).

The logical file is split into 1 MB chunks; chunks are grouped into 4 MB
stripe units and the units round-robin across ``width`` physical files, each
placed in a DIFFERENT DataNode group.  Reads and writes therefore run with
``width``-way parallelism (one thread per physical file) instead of being
serialized inside a single 512 MB HDFS block.

Layout math for chunk ``i`` (chunk = 1 MB, stripe = 4 MB = ``spc`` chunks):
    unit        u = i // spc
    file        f = u % width
    unit-in-file  = u // width
    offset-in-file = (u // width) * stripe + (i % spc) * chunk

``StripedReader.pread`` reads an arbitrary (offset, length) range touching
only the chunks it needs — this is what makes *sharding-aware* checkpoint
resumption possible (each host fetches only its shard's byte ranges).
``StripedReader.pread_many`` batches a whole *set* of ranges (a restore
plan's reads, see repro.ckpt.plan): all chunk sub-reads are grouped per
physical stripe file, each file is opened AT MOST ONCE per call, and the
per-file jobs run on one shared long-lived I/O pool instead of a fresh
``ThreadPoolExecutor`` per read.
"""

from __future__ import annotations

import os
import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Optional, Sequence

from repro.dfs.hdfs import BlockMeta, HdfsCluster

CHUNK = 1 * 1024 * 1024
STRIPE = 4 * 1024 * 1024


class StripeMissingError(RuntimeError):
    """A physical stripe file backing a striped DFS file is missing (or
    truncated): the logical file cannot be read completely.  Raised instead
    of returning silently corrupt bytes; names the exact physical file and
    DataNode group so operators know which replica to repair."""

    def __init__(self, logical_path: str, *, file_index: int, group: int,
                 name: str, detail: str = "missing"):
        self.logical_path = logical_path
        self.file_index = file_index
        self.group = group
        self.name = name
        super().__init__(
            f"striped file '{logical_path}': physical stripe file '{name}' "
            f"(stripe index {file_index}, DataNode group {group}) is "
            f"{detail}")


def pread_many_fallback(pread, ranges, into=None, priority=None):
    """Per-range ``pread_many`` for non-striped readers, matching
    ``StripedReader.pread_many``'s return contract (bytes list, or byte
    counts with ``into`` buffers filled).  Independent ranges run
    concurrently on the shared I/O pool, so the plain path keeps the
    multi-tensor fetch parallelism the old restore had.  ``priority`` is
    accepted for signature parity with ``StripedReader.pread_many`` (the
    plain path is not scheduler-metered)."""
    results: list = [None] * len(ranges)

    def one(i):
        off, ln = ranges[i]
        data = pread(off, ln)
        if into is None:
            results[i] = data
        else:
            memoryview(into[i])[:len(data)] = data
            results[i] = len(data)

    if len(ranges) <= 1:
        for i in range(len(ranges)):
            one(i)
    else:
        pool = shared_io_pool()
        for fu in [pool.submit(one, i) for i in range(len(ranges))]:
            fu.result()
    return results


_IO_POOL: Optional[ThreadPoolExecutor] = None
_IO_POOL_LOCK = threading.Lock()


def shared_io_pool() -> ThreadPoolExecutor:
    """Process-wide long-lived pool for striped-DFS file jobs.

    Every reader/writer shares it, so the per-call executor spawn cost
    (~ms per thread on small boxes) is paid once per process and total
    I/O concurrency stays bounded by the pool size instead of scaling
    with the number of concurrent readers.  Tasks submitted here are pure
    file I/O and never wait on other tasks in this pool, so it cannot
    deadlock.
    """
    global _IO_POOL
    with _IO_POOL_LOCK:
        if _IO_POOL is None:
            _IO_POOL = ThreadPoolExecutor(
                max(4, 2 * (os.cpu_count() or 2)),
                thread_name_prefix="dfs-io")
        return _IO_POOL


@dataclass(frozen=True)
class StripedMeta:
    size: int
    width: int
    chunk: int
    stripe: int
    files: tuple  # (group, name) per physical file

    @property
    def spc(self) -> int:  # chunks per stripe unit
        return self.stripe // self.chunk

    def locate(self, chunk_idx: int) -> tuple[int, int]:
        """-> (file index, offset within that physical file)."""
        u = chunk_idx // self.spc
        f = u % self.width
        off = (u // self.width) * self.stripe + (chunk_idx % self.spc) * self.chunk
        return f, off


class StripedWriter:
    """Parallel striped write of a logical stream."""

    def __init__(self, hdfs: HdfsCluster, path: str, *, width: int = 8,
                 chunk: int = CHUNK, stripe: int = STRIPE,
                 threads: Optional[int] = None):
        assert stripe % chunk == 0
        self.hdfs = hdfs
        self.path = path
        self.width = min(width, hdfs.num_groups)
        self.chunk = chunk
        self.stripe = stripe
        self.threads = threads or self.width
        self._buf = bytearray()
        self._size = 0
        self._flushed = 0
        self._files = []
        self._handles = []
        import zlib
        tag = zlib.crc32(path.encode()) % 10 ** 8
        for f in range(self.width):
            group = (f * max(hdfs.num_groups // self.width, 1)) % hdfs.num_groups
            name = f"stripe_{tag:08d}_{f}"
            self._files.append((group, name))
            self._handles.append(hdfs.open_group_file(group, name, "wb"))
        self._lock = threading.Lock()

    def write(self, data: bytes):
        self._buf += data
        self._size += len(data)
        full = (len(self._buf) // self.chunk) * self.chunk
        if full:
            self._flush(bytes(self._buf[:full]))
            del self._buf[:full]

    def _flush(self, data: bytes):
        meta = self._meta_for(self._size)  # width/chunk/stripe fixed
        start_chunk = self._flushed // self.chunk
        self._flushed += len(data)
        # group chunk writes per file, then write in parallel
        per_file: dict[int, list[tuple[int, bytes]]] = {}
        for j in range(0, len(data), self.chunk):
            ci = start_chunk + j // self.chunk
            f, off = meta.locate(ci)
            per_file.setdefault(f, []).append((off, data[j:j + self.chunk]))

        def write_file(f):
            h = self._handles[f]
            n = 0
            for off, payload in per_file[f]:
                h.seek(off)
                h.write(payload)
                n += len(payload)
            self.hdfs.account_write(n)
            if self.hdfs.throttle:
                with self.hdfs.throttle:
                    self.hdfs.throttle.charge(n)

        # a single-file flush (small archives) runs inline instead of
        # round-tripping through the pool
        if len(per_file) == 1:
            write_file(next(iter(per_file)))
        else:
            pool = shared_io_pool()
            for fu in [pool.submit(write_file, f) for f in per_file]:
                fu.result()

    def _meta_for(self, size: int) -> StripedMeta:
        return StripedMeta(size=size, width=self.width, chunk=self.chunk,
                           stripe=self.stripe, files=tuple(self._files))

    def close(self):
        if self._buf:
            pad = bytes(self._buf)
            self._buf.clear()
            self._flush(pad + b"\0" * ((-len(pad)) % self.chunk))
        for h in self._handles:
            h.close()
        meta = self._meta_for(self._size)
        blocks = [BlockMeta(group=g, path=n, length=0)
                  for g, n in meta.files]
        self.hdfs.register_raw(
            self.path, self._size, blocks,
            attrs={"striped": {
                "size": meta.size, "width": meta.width, "chunk": meta.chunk,
                "stripe": meta.stripe, "files": list(meta.files)}})

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class StripedReader:
    """Parallel positional reads of a striped file.

    All read paths funnel through :meth:`pread_many`: sub-reads are grouped
    per physical stripe file, sorted and merged into sequential runs, each
    file is opened at most once per call, and the per-file jobs run on the
    shared long-lived I/O pool (``threads`` is kept for API compat but the
    pool bounds actual concurrency).
    """

    def __init__(self, hdfs: HdfsCluster, path: str,
                 threads: Optional[int] = None,
                 pool: Optional[ThreadPoolExecutor] = None,
                 sched=None, priority: int = 0):
        self.hdfs = hdfs
        self.path = path
        raw = hdfs.attrs(path)["striped"]
        self.meta = StripedMeta(size=raw["size"], width=raw["width"],
                                chunk=raw["chunk"], stripe=raw["stripe"],
                                files=tuple(tuple(f) for f in raw["files"]))
        self.threads = threads or self.meta.width
        self._pool = pool
        # optional bandwidth-aware scheduler (repro.core.pipeline
        # IOScheduler): each per-file read job holds one "dfs" token, so
        # concurrent readers of different priority classes cannot convoy
        # each other — a CRITICAL params-wave pread is granted the next
        # free token even when a DEFERRED opt-state wave queued first
        self.sched = sched
        self.priority = priority

    @property
    def size(self) -> int:
        return self.meta.size

    def pread(self, offset: int, length: int) -> bytes:
        return self.pread_many([(offset, length)])[0]

    def pread_many(self, ranges: Sequence[tuple[int, int]],
                   into: Optional[Sequence] = None,
                   priority: Optional[int] = None):
        """Batched positional reads.

        ``ranges``: (offset, length) pairs over the logical stream; each is
        clamped at EOF like :meth:`pread`.  Without ``into``, returns one
        ``bytes`` per range.  With ``into`` — parallel writable buffers
        (anything supporting the buffer protocol, e.g. numpy uint8 views) —
        bytes land zero-copy via ``readinto`` and the per-range byte counts
        are returned.  ``priority`` overrides the reader's scheduler
        priority class for this call (ignored without a scheduler).

        Raises :class:`StripeMissingError` if a physical stripe file is
        gone or short.
        """
        m = self.meta
        prio = self.priority if priority is None else priority
        clamped: list[tuple[int, int]] = []
        views: list[Optional[memoryview]] = []
        out: list = []
        for i, (off, ln) in enumerate(ranges):
            ln = max(0, min(ln, m.size - off))
            clamped.append((off, ln))
            if into is None:
                buf = bytearray(ln)
                out.append(buf)
                views.append(memoryview(buf))
            else:
                out.append(ln)
                views.append(memoryview(into[i]) if ln else None)

        # chunk sub-reads grouped per physical file:
        # (file_offset, length, range_idx, dest_offset)
        jobs: dict[int, list[tuple[int, int, int, int]]] = {}
        for i, (off, ln) in enumerate(clamped):
            if ln <= 0:
                continue
            first = off // m.chunk
            last = (off + ln - 1) // m.chunk
            for ci in range(first, last + 1):
                f, base = m.locate(ci)
                lo = max(off - ci * m.chunk, 0)
                hi = min(off + ln - ci * m.chunk, m.chunk)
                dst = ci * m.chunk + lo - off
                jobs.setdefault(f, []).append((base + lo, hi - lo, i, dst))

        # sort by file offset and merge file- and dest-contiguous sub-reads
        # so full-tensor restores become a few big sequential readintos
        for f, subs in jobs.items():
            subs.sort()
            merged = [subs[0]]
            for off, ln, i, dst in subs[1:]:
                poff, pln, pi, pdst = merged[-1]
                if off == poff + pln and i == pi and dst == pdst + pln:
                    merged[-1] = (poff, pln + ln, pi, pdst)
                else:
                    merged.append((off, ln, i, dst))
            jobs[f] = merged

        def read_file(f):
            if self.sched is not None:
                nbytes = sum(ln for _, ln, _, _ in jobs[f])
                with self.sched.slot("dfs", priority=prio, nbytes=nbytes):
                    return read_file_inner(f)
            return read_file_inner(f)

        def read_file_inner(f):
            group, name = m.files[f]
            n = 0
            try:
                h = self.hdfs.open_group_file(group, name, "rb")
            except FileNotFoundError as e:
                raise StripeMissingError(self.path, file_index=f,
                                         group=group, name=name) from e
            with h:
                for off, ln, i, dst in jobs[f]:
                    h.seek(off)
                    got = h.readinto(views[i][dst:dst + ln])
                    if got != ln:
                        raise StripeMissingError(
                            self.path, file_index=f, group=group, name=name,
                            detail=f"truncated (wanted {ln} bytes at offset "
                                   f"{off}, got {got})")
                    n += ln
            self.hdfs.account_read(n)
            if self.hdfs.throttle:
                with self.hdfs.throttle:
                    self.hdfs.throttle.charge(n)

        # single-file calls (sub-stripe ranges) skip the pool entirely
        if len(jobs) == 1:
            read_file(next(iter(jobs)))
        elif jobs:
            pool = self._pool or shared_io_pool()
            futs = [pool.submit(read_file, f) for f in jobs]
            for fu in futs:
                fu.result()
        if into is None:
            return [bytes(b) for b in out]
        return out

    def read_all(self) -> bytes:
        return self.pread(0, self.meta.size)


def write_striped(hdfs: HdfsCluster, path: str, data: bytes, *,
                  width: int = 8, chunk: int = CHUNK, stripe: int = STRIPE):
    with StripedWriter(hdfs, path, width=width, chunk=chunk,
                       stripe=stripe) as w:
        w.write(data)
