from repro.dfs.hdfs import HdfsCluster  # noqa: F401
from repro.dfs.striped import (StripedWriter, StripedReader,  # noqa: F401
                               StripeMissingError, shared_io_pool)
from repro.dfs.fuse import HdfsFuseMount  # noqa: F401
