"""HDFS-FUSE: a file-like mounted view of the DFS.

The paper mounts remote HDFS directories into worker containers via a FUSE
sidecar; kernel mounts are unavailable in this sandbox, so the "mount" is an
object exposing ``open(path)`` -> file-like handles.  Striped files
transparently get the parallel reader.

A mount may carry an ``IOScheduler`` (``sched=``): every pread — striped
or plain — then runs under a "dfs" slot token at the mount's default
``priority``, overridable per read.  Leave ``sched`` unset when a higher
layer already meters the reads (e.g. ``EnvCache`` passes its own
scheduler) — nesting two "dfs" slot acquisitions on one thread would
double-count and risk token starvation.
"""

from __future__ import annotations

import io
from typing import Optional

from repro.dfs.hdfs import HdfsCluster
from repro.dfs.striped import StripedReader


class HdfsFuseFile:
    """Read-only file handle with read/seek/pread over a DFS file."""

    def __init__(self, mount: "HdfsFuseMount", path: str):
        self._mount = mount
        self.path = path
        self._pos = 0
        meta = mount.hdfs.attrs(path)
        if "striped" in meta:
            self._reader: Optional[StripedReader] = StripedReader(
                mount.hdfs, path, sched=mount.sched,
                priority=mount.priority)
            self._size = self._reader.size
        else:
            self._reader = None
            self._size = mount.hdfs.size(path)

    def __len__(self):
        return self._size

    def seek(self, pos: int, whence: int = io.SEEK_SET) -> int:
        if whence == io.SEEK_SET:
            self._pos = pos
        elif whence == io.SEEK_CUR:
            self._pos += pos
        else:
            self._pos = self._size + pos
        return self._pos

    def tell(self) -> int:
        return self._pos

    def pread(self, offset: int, length: int, priority=None) -> bytes:
        """Single positional read.  Delegates to ``pread_many`` so the
        scheduling class survives — ``pread`` used to drop it while its
        batched sibling forwarded it, and single-range callers silently
        lost their priority."""
        return self.pread_many([(offset, length)], priority=priority)[0]

    def pread_many(self, ranges, into=None, priority=None):
        """Batched ranged reads (see ``StripedReader.pread_many``).  Plain
        files fall back to per-range preads with the same return contract,
        metered under the mount's scheduler when it has one."""
        if self._reader is not None:
            return self._reader.pread_many(ranges, into=into,
                                           priority=priority)
        from repro.dfs.striped import pread_many_fallback
        sched = self._mount.sched
        if sched is None:
            return pread_many_fallback(self._pread_raw, ranges, into=into)
        prio = self._mount.priority if priority is None else priority
        nbytes = sum(max(0, ln) for _, ln in ranges)
        with sched.slot("dfs", priority=prio, nbytes=nbytes):
            return pread_many_fallback(self._pread_raw, ranges, into=into)

    def _pread_raw(self, offset: int, length: int) -> bytes:
        return self._mount.hdfs.pread(self.path, offset, length)

    def read(self, length: int = -1) -> bytes:
        if length < 0:
            length = self._size - self._pos
        data = self.pread(self._pos, length)
        self._pos += len(data)
        return data

    def close(self):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        pass


class HdfsFuseMount:
    """The 'mounted directory': open() remote paths as local file objects."""

    def __init__(self, hdfs: HdfsCluster, prefix: str = "", *,
                 sched=None, priority: int = 0):
        self.hdfs = hdfs
        self.prefix = prefix.rstrip("/")
        self.sched = sched
        self.priority = priority

    def _full(self, path: str) -> str:
        return f"{self.prefix}/{path.lstrip('/')}" if self.prefix else path

    def open(self, path: str) -> HdfsFuseFile:
        return HdfsFuseFile(self, self._full(path))

    def exists(self, path: str) -> bool:
        return self.hdfs.exists(self._full(path))

    def listdir(self, path: str = "") -> list[str]:
        return self.hdfs.listdir(self._full(path) if path else self.prefix)

    def write(self, path: str, data: bytes, striped: bool = False,
              width: int = 8, placement=None):
        """``placement``: optional repro.fabric.placement.Placement (or
        kind string) for striped writes — replicated/erasure durability."""
        full = self._full(path)
        if striped:
            from repro.dfs.striped import write_striped
            write_striped(self.hdfs, full, data, width=width,
                          placement=placement)
        else:
            self.hdfs.write(full, data)
