"""A userspace distributed-filesystem model over real directories.

Mirrors the HDFS structure the paper describes (§4.4): a NameNode holding
file -> block metadata, and DataNode *replication groups* holding the block
data.  In the original layout, a file is written as sequential large blocks
(512 MB by default) and **each block lives inside a single group**, so reads
of one block are served by one group — this is the I/O-parallelism limit the
striped layout (repro.dfs.striped) removes.

Real files + real threads; an optional ``ThrottleModel`` adds deterministic
service delay so laptop-scale benchmarks expose the same contention shapes as
the production measurements (tests run with no throttle).
"""

from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Optional

DEFAULT_BLOCK = 512 * 1024 * 1024


class ThrottleModel:
    """Deterministic shared-bandwidth delay model.

    ``bandwidth`` bytes/s shared among concurrent readers of one source;
    ``per_stream`` caps a single sequential stream (the reason parallel
    striped reads and multi-threaded prefetch beat serial faulting);
    above ``throttle_after`` concurrent requests the source rate-limits by
    ``throttle_factor`` (the paper's SCM/registry behaviour, §3.4).
    ``timescale`` shrinks wall-clock sleeps so tests stay fast.
    """

    def __init__(self, bandwidth: float = 1e9, throttle_after: int = 64,
                 throttle_factor: float = 4.0, timescale: float = 1e-3,
                 per_stream: float = float("inf")):
        self.bandwidth = bandwidth
        self.per_stream = per_stream
        self.throttle_after = throttle_after
        self.throttle_factor = throttle_factor
        self.timescale = timescale
        self._lock = threading.Lock()
        self._active = 0
        self.served_bytes = 0
        self.max_concurrency = 0

    def __enter__(self):
        with self._lock:
            self._active += 1
            self.max_concurrency = max(self.max_concurrency, self._active)
        return self

    def __exit__(self, *exc):
        with self._lock:
            self._active -= 1

    def delay(self, nbytes: int) -> float:
        with self._lock:
            k = max(self._active, 1)
            self.served_bytes += nbytes
        rate = min(self.bandwidth / k, self.per_stream)
        if k > self.throttle_after:
            rate /= self.throttle_factor
        return nbytes / rate * self.timescale

    def charge(self, nbytes: int):
        time.sleep(self.delay(nbytes))


@dataclass
class BlockMeta:
    group: int
    path: str          # path within the group dir
    length: int


@dataclass
class FileMeta:
    size: int
    block_size: int
    blocks: list = field(default_factory=list)  # list[BlockMeta]
    attrs: dict = field(default_factory=dict)


class HdfsCluster:
    """NameNode metadata + DataNode-group directories."""

    def __init__(self, root: str | Path, num_groups: int = 8,
                 block_size: int = DEFAULT_BLOCK,
                 throttle: Optional[ThrottleModel] = None,
                 num_regions: int = 1):
        self.root = Path(root)
        self.num_groups = num_groups
        self.block_size = block_size
        self.throttle = throttle
        # region tier over the DataNode groups: contiguous runs of
        # num_groups // num_regions groups form one region each (the
        # remainder folds into the last region).  Region-spread
        # replicated placement (repro.fabric.placement) uses this to put
        # each mirror a whole region away from its data file.
        if not 1 <= num_regions <= num_groups:
            raise ValueError(
                f"num_regions must be in [1, num_groups={num_groups}], "
                f"got {num_regions}")
        self.num_regions = num_regions
        self._meta: dict[str, FileMeta] = {}
        self._lock = threading.Lock()
        self._counter = 0
        # deterministic byte accounting (always on, unlike the optional
        # throttle): every DataNode read/write lands here, including the
        # per-group files behind striped layouts.  The perf-regression
        # tests assert on these counters instead of wall clock.
        self.read_bytes = 0
        self.write_bytes = 0
        # storage-fabric degraded-mode counters (see repro.dfs.striped):
        # aggregated cluster-wide so the runtime can report per-run deltas
        # without holding every short-lived reader
        self.fabric_stats = {"degraded_reads": 0, "reconstructed_bytes": 0,
                             "reconstruction_read_bytes": 0,
                             "corrupt_chunks": 0,
                             # restore-ahead prefetch (repro.core.bootseer):
                             # checkpoint bytes staged into / served from
                             # node caches instead of DFS preads
                             "restore_ahead_prefetch_bytes": 0,
                             "restore_ahead_hit_bytes": 0}
        for g in range(num_groups):
            (self.root / f"group{g:02d}").mkdir(parents=True, exist_ok=True)
        self._meta_path = self.root / "namenode.json"
        if self._meta_path.exists():
            self._load_meta()

    # ----- namenode persistence -----

    def _load_meta(self):
        raw = json.loads(self._meta_path.read_text())
        self._counter = raw.get("counter", 0)
        self._meta = {
            p: FileMeta(size=m["size"], block_size=m["block_size"],
                        blocks=[BlockMeta(**b) for b in m["blocks"]],
                        attrs=m.get("attrs", {}))
            for p, m in raw["files"].items()}

    def _save_meta(self):
        raw = {"counter": self._counter, "files": {
            p: {"size": m.size, "block_size": m.block_size,
                "blocks": [vars(b) for b in m.blocks], "attrs": m.attrs}
            for p, m in self._meta.items()}}
        tmp = self._meta_path.with_suffix(".tmp")
        tmp.write_text(json.dumps(raw))
        tmp.replace(self._meta_path)

    # ----- block placement -----

    def _alloc_block(self, logical_path: str, idx: int) -> tuple[int, Path]:
        with self._lock:
            self._counter += 1
            n = self._counter
        import zlib
        group = (zlib.crc32(logical_path.encode()) + idx) % self.num_groups
        rel = f"blk_{n:08d}"
        return group, self.root / f"group{group:02d}" / rel

    def _block_file(self, bm: BlockMeta) -> Path:
        return self.root / f"group{bm.group:02d}" / bm.path

    def group_region(self, group: int) -> int:
        """The region index a DataNode group belongs to (contiguous
        partition; the remainder groups fold into the last region)."""
        gpr = max(self.num_groups // self.num_regions, 1)
        return min(group // gpr, self.num_regions - 1)

    def region_stride(self) -> int:
        """Groups per region — the offset that moves a placement one
        whole region over."""
        return max(self.num_groups // self.num_regions, 1)

    # ----- byte accounting -----

    def account_read(self, nbytes: int):
        with self._lock:
            self.read_bytes += int(nbytes)

    def account_write(self, nbytes: int):
        with self._lock:
            self.write_bytes += int(nbytes)

    def account_fabric(self, **counters: int):
        with self._lock:
            for key, n in counters.items():
                self.fabric_stats[key] = self.fabric_stats.get(key, 0) + n

    def reset_counters(self):
        with self._lock:
            self.read_bytes = 0
            self.write_bytes = 0

    # ----- public API -----

    def exists(self, path: str) -> bool:
        return path in self._meta

    def listdir(self, prefix: str) -> list[str]:
        prefix = prefix.rstrip("/") + "/"
        return sorted(p for p in self._meta if p.startswith(prefix))

    def delete(self, path: str):
        meta = self._meta.pop(path, None)
        if meta:
            for bm in meta.blocks:
                self._block_file(bm).unlink(missing_ok=True)
            self._save_meta()

    def size(self, path: str) -> int:
        return self._meta[path].size

    def write(self, path: str, data: bytes, attrs: Optional[dict] = None):
        """Write a file as sequential blocks (original HDFS layout)."""
        meta = FileMeta(size=len(data), block_size=self.block_size,
                        attrs=attrs or {})
        for idx in range(0, max(1, -(-len(data) // self.block_size))):
            lo = idx * self.block_size
            chunk = data[lo:lo + self.block_size]
            group, blk_path = self._alloc_block(path, idx)
            blk_path.write_bytes(chunk)
            meta.blocks.append(BlockMeta(group=group, path=blk_path.name,
                                         length=len(chunk)))
            self.account_write(len(chunk))
            if self.throttle:
                with self.throttle:
                    self.throttle.charge(len(chunk))
        with self._lock:
            self._meta[path] = meta
            self._save_meta()

    def read(self, path: str) -> bytes:
        return self.pread(path, 0, self._meta[path].size)

    def pread(self, path: str, offset: int, length: int) -> bytes:
        """Positional read.  In the original layout this walks blocks
        SEQUENTIALLY (each block lives in one group) — the baseline the
        paper's striping beats."""
        meta = self._meta[path]
        length = min(length, meta.size - offset)
        if length <= 0:
            return b""
        out = bytearray()
        bs = meta.block_size
        first = offset // bs
        last = (offset + length - 1) // bs
        for idx in range(first, last + 1):
            bm = meta.blocks[idx]
            lo = max(offset - idx * bs, 0)
            hi = min(offset + length - idx * bs, bm.length)
            with open(self._block_file(bm), "rb") as f:
                f.seek(lo)
                data = f.read(hi - lo)
            self.account_read(len(data))
            if self.throttle:
                with self.throttle:
                    self.throttle.charge(len(data))
            out += data
        return bytes(out)

    def attrs(self, path: str) -> dict:
        return self._meta[path].attrs

    # striped files need raw per-group file handles
    def open_group_file(self, group: int, name: str, mode: str = "rb"):
        return open(self.root / f"group{group:02d}" / name, mode)

    def register_raw(self, path: str, size: int, blocks: list[BlockMeta],
                     attrs: Optional[dict] = None,
                     block_size: Optional[int] = None):
        """Register an externally-written (e.g. striped) physical layout."""
        with self._lock:
            self._meta[path] = FileMeta(
                size=size, block_size=block_size or self.block_size,
                blocks=blocks, attrs=attrs or {})
            self._save_meta()
