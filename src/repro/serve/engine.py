"""Batched serving engine: continuous-batch prefill + greedy/temperature
decode over a shared KV cache."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serve.step import jit_decode_step, jit_prefill


@dataclass
class Request:
    prompt: np.ndarray              # [prompt_len] int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    generated: list = field(default_factory=list)


class ServeEngine:
    """Minimal batched engine: pads a request batch to a fixed shape,
    prefills once, then decodes step-by-step for all sequences together."""

    def __init__(self, model: Model, params, *, batch: int, cache_len: int,
                 tune_profile=None):
        self.model = model
        self.params = params
        self.batch = batch
        self.cache_len = cache_len
        # kernel launch configs for this replica: installed as the
        # ambient profile around generate(), so the prefill/decode
        # traces resolve tuned block shapes instead of defaults
        self.tune_profile = tune_profile
        self._prefill = jit_prefill(model, batch, cache_len)
        self._decode = jit_decode_step(model, batch, cache_len)

    @classmethod
    def from_checkpoint(cls, model: Model, checkpointer, step=None, *,
                        batch: int, cache_len: int, sched=None,
                        priority=None, tune_store=None) -> "ServeEngine":
        """Build an engine whose params come from a checkpoint via the
        planned restore path — ``restore_planned(sched=, priority=
        CRITICAL)`` — instead of a raw reader: serving cold-starts are
        exactly the startup I/O the IOScheduler exists to arbitrate, so
        a replica booting under load competes for DFS tokens at CRITICAL
        (params gate time-to-first-token) rather than bypassing the
        scheduler.  Params-only: no optimizer wave is planned or read.

        ``tune_store``: a ``repro.tune.store.ProfileStore`` — the
        replica fetches the cluster's TuningProfile (tiny, metered,
        DEFERRED by the store's own default priority: it never gates
        time-to-first-token) so a serving cold-start inherits tuned
        kernel configs with zero re-tuning; a missing or corrupt
        profile silently keeps the defaults.
        """
        from repro.core.pipeline import CRITICAL
        if step is None:
            step = checkpointer.latest_step()
            if step is None:
                raise FileNotFoundError(
                    "from_checkpoint: no checkpoint steps found under "
                    f"{checkpointer.base!r}")
        like = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        (params,) = checkpointer.restore_planned(
            step, like, sched=sched,
            priority=CRITICAL if priority is None else priority)
        tune_profile = tune_store.fetch() if tune_store is not None \
            else None
        return cls(model, params, batch=batch, cache_len=cache_len,
                   tune_profile=tune_profile)

    def generate(self, requests: list[Request], seed: int = 0) -> list[Request]:
        if self.tune_profile is None:
            return self._generate(requests, seed)
        from repro.tune.profile import use_profile
        with use_profile(self.tune_profile):
            return self._generate(requests, seed)

    def _generate(self, requests: list[Request], seed: int = 0) -> list[Request]:
        assert len(requests) <= self.batch
        # pad the request list to the engine batch
        while len(requests) < self.batch:
            requests.append(Request(prompt=np.zeros(1, np.int32),
                                    max_new_tokens=0))
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.batch, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})

        key = jax.random.key(seed)
        max_new = max(r.max_new_tokens for r in requests)
        pos = plen
        last = None
        for step in range(max_new):
            if last is None:
                nxt = self._sample(logits, requests, key, step)
            else:
                nxt = last
            logits, cache = self._decode(
                self.params, jnp.asarray(nxt)[:, None], cache,
                jnp.int32(pos))
            pos += 1
            out = self._sample(logits, requests, key, step)
            for i, r in enumerate(requests):
                if step < r.max_new_tokens:
                    r.generated.append(int(out[i]))
            last = out
        return requests

    def _sample(self, logits, requests, key, step):
        logits = np.asarray(logits, np.float32)
        out = np.argmax(logits, axis=-1).astype(np.int32)
        for i, r in enumerate(requests):
            if r.temperature > 0:
                k = jax.random.fold_in(jax.random.fold_in(key, step), i)
                p = jax.nn.softmax(jnp.asarray(logits[i]) / r.temperature)
                out[i] = int(jax.random.choice(k, logits.shape[-1], p=p))
        return out
