"""Jitted prefill / decode steps with explicit shardings (these are the
functions the decode-shape dry-runs lower)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.model import Model


def _named(model: Model, tree):
    r = model.rules
    return jax.tree.map(r.named, tree, is_leaf=lambda x: isinstance(x, P))


def jit_prefill(model: Model, batch: int, cache_len: int, *,
                with_embeddings: bool = False, with_mrope: bool = False):
    r = model.rules
    dp = r.dp(batch)
    bspecs: dict = {}
    if with_embeddings:
        bspecs["embeddings"] = P(dp, None, None)
    else:
        bspecs["tokens"] = P(dp, None)
    if with_mrope:
        bspecs["mrope_pos"] = P(dp, None, None)
    pspecs = model.param_specs()
    cspecs = model.cache_specs(batch, cache_len)

    def fn(params, batch_in):
        return model.prefill(params, batch_in, cache_len=cache_len)

    return jax.jit(
        fn,
        in_shardings=(_named(model, pspecs), _named(model, bspecs)),
        out_shardings=(r.named(P(dp, r.tp(model.cfg.vocab_size))),
                       _named(model, cspecs)),
    )


def jit_decode_step(model: Model, batch: int, cache_len: int, *,
                    donate_cache: bool = True):
    r = model.rules
    dp = r.dp(batch)
    pspecs = model.param_specs()
    cspecs = model.cache_specs(batch, cache_len)
    return jax.jit(
        model.decode_step,
        in_shardings=(_named(model, pspecs), r.named(P(dp, None)),
                      _named(model, cspecs), r.named(P())),
        out_shardings=(r.named(P(dp, r.tp(model.cfg.vocab_size))),
                      _named(model, cspecs)),
        donate_argnums=(2,) if donate_cache else (),
    )
