from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.step import jit_prefill, jit_decode_step  # noqa: F401
